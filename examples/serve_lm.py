"""Batched serving example: continuous batching over decode slots.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma-2b --requests 6
"""
import argparse
import importlib

import jax
import numpy as np

from repro.launch.serve import ContinuousBatcher, Request
from repro.models import LanguageModel


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()

    mod = importlib.import_module(
        "repro.configs." + args.arch.replace("-", "_").replace(".", "_"))
    cfg = mod.smoke()
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))

    batcher = ContinuousBatcher(model, params, n_slots=args.slots,
                                max_len=64)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size, 6).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    stats = batcher.run(reqs)
    print(f"[serve {args.arch}] {stats['requests']} requests, "
          f"{stats['tokens']} tokens in {stats['wall_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s, {stats['ticks']} ticks, "
          f"{args.slots} slots)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt {r.prompt} -> out {r.out}")


if __name__ == "__main__":
    main()
