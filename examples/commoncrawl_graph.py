"""The paper's §5 use case end-to-end: mine a web-based inter-firm network
from (synthetic) Common-Crawl data with the 4-asset pipeline, partitioned by
crawl-month x domain-shard, dispatched across platforms by cost.

    PYTHONPATH=src python examples/commoncrawl_graph.py
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from benchmarks.cc_pipeline import build_graph  # noqa: E402
from repro.core import (CostModel, DynamicClientFactory, MessageReader,
                        MultiPartitions, Objective, RunCoordinator,
                        StaticPartitions, default_catalog)

PARTS = MultiPartitions(dims=(
    ("time", StaticPartitions(("2023-10", "2023-11"))),
    ("domain", StaticPartitions(("shard-0", "shard-1"))),
))


def main() -> None:
    graph = build_graph(partitions=PARTS)
    reader = MessageReader()
    factory = DynamicClientFactory(default_catalog(), CostModel(),
                                   Objective.balanced(), sim_seed=3)
    coord = RunCoordinator(graph, factory, reader=reader)

    # global DAG-level plan first: critical path on fast platforms, slack
    # tasks on cheap ones — then execute it (greedy fallback on failover)
    plan = coord.plan(["graph_aggr"])
    print("run plan preview:")
    print(plan.table())
    print()

    report = coord.materialize(["graph_aggr"], plan=plan)
    print(report.summary())

    agg = coord.store.get("graph_aggr", "2023-10/shard-0")
    print(f"\ndomain-level graph (2023-10/shard-0): "
          f"{len(agg['weight'])} inter-domain edges, "
          f"{agg['n_domains']} domains")
    top = sorted(zip(agg["weight"], agg["src_domain"], agg["dst_domain"]),
                 reverse=True)[:5]
    for w, s, d in top:
        print(f"  domain {s:>3} -> domain {d:>3}  weight {w:.2f}")

    print("\nper-platform outcomes (Fig 3 view):", reader.outcome_counts())
    print("cost by asset (Fig 5 view):",
          {k: round(v, 2) for k, v in report.by_asset_cost().items()})


if __name__ == "__main__":
    main()
