"""Quickstart: define two assets, let the Dynamic Factory pick platforms,
materialize, and inspect cost/telemetry.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (AssetGraph, ComputeProfile, CostModel,
                        DynamicClientFactory, Objective, RunCoordinator,
                        StaticPartitions, asset, default_catalog)

parts = StaticPartitions(("2024-01", "2024-02"))


@asset(name="raw_counts", partitions=parts,
       compute=ComputeProfile(work_chip_hours=50.0, speedup_class="scan"))
def raw_counts(ctx):
    ctx.log("HEARTBEAT", stage="counting")
    return {"month": ctx.partition_key, "count": 1000 + len(ctx.partition_key)}


@asset(name="report", deps=("raw_counts",),
       compute=ComputeProfile(work_chip_hours=1.0, speedup_class="light"))
def report(ctx, raw_counts):
    total = sum(v["count"] for v in raw_counts.values())
    return {"total": total, "months": sorted(raw_counts)}


def main() -> None:
    graph = AssetGraph([raw_counts, report])
    factory = DynamicClientFactory(default_catalog(), CostModel(),
                                   Objective.balanced(), sim_seed=42)
    coord = RunCoordinator(graph, factory)
    rep = coord.materialize(["report"])
    print(rep.summary())
    print("result:", coord.store.get("report", "__all__"))
    print("total simulated cost: $%.2f" % rep.total_cost)
    for name, spec in (("raw_counts", graph["raw_counts"]),
                       ("report", graph["report"])):
        platform, est = factory.choose(spec)
        print(f"factory would run {name!r} on {platform.name} "
              f"(${est.total_usd:.2f}, {est.duration_s / 3600:.2f} h)")


if __name__ == "__main__":
    main()
