"""End-to-end training driver example: orchestrated LM training.

The orchestrator treats each training STAGE (a span of steps ending in a
checkpoint) as an asset, so platform selection / retries / caching apply to
training itself: a preempted stage re-runs from its upstream checkpoint.

Defaults are CPU-sized (a reduced config, ~1 minute).  On real hardware the
same driver takes --full and a pod mesh, e.g.:
    python examples/train_lm.py --arch gemma-2b --stages 20 --steps 100
    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --full ...

    PYTHONPATH=src python examples/train_lm.py
"""
import argparse

from repro.core import (AssetGraph, ComputeProfile, CostModel,
                        DynamicClientFactory, Objective, RunCoordinator,
                        asset, default_catalog)
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--stages", type=int, default=3)
    ap.add_argument("--steps", type=int, default=10, help="steps per stage")
    ap.add_argument("--ckpt-dir", default="artifacts/train_lm_example")
    args = ap.parse_args()

    total = {"n": 0}
    stage_assets = []
    for i in range(args.stages):
        deps = (f"stage{i - 1}",) if i else ()

        def stage_fn(ctx, _i=i, **up):
            out = train(arch=args.arch, smoke=True,
                        steps=(_i + 1) * args.steps, global_batch=4,
                        seq_len=64, peak_lr=5e-3, log_every=args.steps,
                        ckpt_dir=args.ckpt_dir, resume=True)
            total["n"] = out["steps"]
            return {"final_loss": out["final_loss"], "steps": out["steps"]}

        stage_assets.append(asset(
            name=f"stage{i}", deps=deps,
            compute=ComputeProfile(work_chip_hours=120.0,
                                   speedup_class="train"),
        )(stage_fn))

    graph = AssetGraph(stage_assets)
    factory = DynamicClientFactory(default_catalog(), CostModel(),
                                   Objective.balanced(), sim_seed=1)
    coord = RunCoordinator(graph, factory)
    report = coord.materialize([f"stage{args.stages - 1}"])
    print(report.summary())
    last = coord.store.get(f"stage{args.stages - 1}", "__all__")
    print(f"trained {last['steps']} steps total; "
          f"final loss {last['final_loss']:.4f}")


if __name__ == "__main__":
    main()
