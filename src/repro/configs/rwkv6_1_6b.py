"""rwkv6-1.6b (Finch) [arXiv:2404.05892; hf:RWKV/rwkv-6-world-1b6].

Attention-free: 24L, d_model=2048 (32 heads of size 64), channel-mix
d_ff=7168 (3.5x), vocab=65536.  Data-dependent decay via LoRA-projected
token-shift mixes (the Finch contribution).  O(1)/token state => long_500k
runs; train/prefill use the chunked-parallel form (kernels/linear_scan).
"""
from repro.configs.base import ModelConfig, register


@register("rwkv6-1.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        source="arXiv:2404.05892",
        n_layers=24,
        d_model=2048,
        n_heads=32,  # d_model / rwkv_head_size
        n_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab_size=65536,
        layer_pattern=("rwkv6",),
        mlp_type="dense",  # channel-mix handled by the rwkv block itself
        norm_type="layernorm",
        norm_eps=1e-5,
        pos_type="none",
        embed_norm=True,  # RWKV ln0
        rwkv_head_size=64,
        rwkv_decay_lora=64,
        rwkv_mix_lora=32,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=224, vocab_size=256, rwkv_head_size=16, rwkv_decay_lora=16,
        rwkv_mix_lora=8, remat="none",
    )
