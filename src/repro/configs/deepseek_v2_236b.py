"""deepseek-v2-236b [arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2].

60L, d_model=5120, 128 heads with **MLA** (q_lora=1536, kv_lora=512,
qk_nope=128, qk_rope=64, v_head=128); MoE with 160 routed experts top-6 +
2 shared experts, expert d_ff=1536, first layer dense (d_ff=12288);
vocab=102400.  The MoE all-to-all makes this the paper-representative
collective-bound hillclimb cell.
"""
from repro.configs.base import ModelConfig, register


@register("deepseek-v2-236b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        source="arXiv:2405.04434",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=12288,  # dense first layer
        vocab_size=102400,
        mlp_type="glu",
        act="silu",
        pos_type="rope",
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        n_experts=160,
        top_k=6,
        n_shared_experts=2,
        d_ff_expert=1536,
        first_dense_layers=1,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=192, vocab_size=256, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        n_experts=8, top_k=2, n_shared_experts=1, d_ff_expert=48,
        first_dense_layers=1, remat="none",
    )
