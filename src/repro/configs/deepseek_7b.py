"""deepseek-7b [arXiv:2401.02954; hf:deepseek-ai/deepseek-llm-7b-base].

Llama-architecture dense baseline: 30L, d_model=4096, 32 heads (MHA, kv=32),
d_ff=11008, vocab=102400, SwiGLU, RoPE.
"""
from repro.configs.base import ModelConfig, register


@register("deepseek-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b",
        family="dense",
        source="arXiv:2401.02954",
        n_layers=30,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        head_dim=128,
        d_ff=11008,
        vocab_size=102400,
        mlp_type="glu",
        act="silu",
        pos_type="rope",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=192, vocab_size=256, remat="none",
    )
