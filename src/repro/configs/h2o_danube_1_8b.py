"""h2o-danube-1.8b [arXiv:2401.16818; hf:h2oai/h2o-danube-1.8b-base].

Llama+Mistral mix: 24L, d_model=2560, 32 heads / 8 KV (GQA), d_ff=6912,
vocab=32000, SwiGLU, RoPE, sliding-window attention (Mistral-style, w=4096).
SWA makes this arch sub-quadratic => the long_500k cell runs (ring-buffer KV).
"""
from repro.configs.base import ModelConfig, register


@register("h2o-danube-1.8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        source="arXiv:2401.16818",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=80,
        d_ff=6912,
        vocab_size=32000,
        layer_pattern=("swa",),
        window=4096,
        mlp_type="glu",
        act="silu",
        pos_type="rope",
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab_size=256, window=16, remat="none",
    )
