from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeSpec,
    applicable_shapes,
    get_config,
    list_configs,
    register,
)

# Import arch modules for registration side effects.
from repro.configs import (  # noqa: F401
    whisper_medium,
    h2o_danube_1_8b,
    gemma_2b,
    minicpm3_4b,
    deepseek_7b,
    recurrentgemma_9b,
    deepseek_v2_236b,
    granite_moe_1b_a400m,
    qwen2_vl_72b,
    rwkv6_1_6b,
)
