"""minicpm3-4b [hf:openbmb/MiniCPM3-4B].

62L, d_model=2560, 40 heads, d_ff=6400, vocab=73448, **MLA**
(q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head=64).
MiniCPM's muP-style scale factors (scale_emb/scale_depth) are orthogonal to
the systems scope and omitted (noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig, register


@register("minicpm3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        source="hf:openbmb/MiniCPM3-4B",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        head_dim=64,
        d_ff=6400,
        vocab_size=73448,
        mlp_type="glu",
        act="silu",
        pos_type="rope",
        use_mla=True,
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16, remat="none",
    )
