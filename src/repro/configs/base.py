"""Model + shape configuration registry for the assigned architectures.

Every architecture from the assignment pool is a ``ModelConfig``; the four
assigned input shapes are ``ShapeSpec``s.  ``applicable_shapes`` implements
the assignment rules (long_500k only for sub-quadratic archs; decode only for
archs with a decoder — all ten have one).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    source: str  # public citation for the config
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # block composition --------------------------------------------------
    # layer_pattern cycles over layers; entries: attn | swa | rglru | rwkv6
    layer_pattern: tuple[str, ...] = ("attn",)
    window: int = 0  # sliding/local attention window (swa layers)
    mlp_type: str = "glu"  # glu | dense
    act: str = "silu"  # silu | gelu
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-6
    gemma_norm: bool = False  # RMSNorm computes (1 + w) * x_hat

    # positions -----------------------------------------------------------
    pos_type: str = "rope"  # rope | mrope | sinusoidal | learned | none
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # fraction of head_dim that is rotated
    mrope_sections: tuple[int, ...] = ()  # in freq pairs; sums to rotated/2

    # MLA (DeepSeek-V2 / MiniCPM3) -----------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0  # 0 => no q compression
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE -------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0  # leading dense layers before MoE layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # encoder-decoder --------------------------------------------------------
    enc_dec: bool = False
    n_enc_layers: int = 0

    # recurrent (Griffin RG-LRU) ---------------------------------------------
    lru_width: int = 0
    conv_width: int = 4

    # RWKV-6 ------------------------------------------------------------------
    rwkv_head_size: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # modality frontend (STUB per assignment: input_specs provides embeddings)
    frontend: str = "none"  # none | audio | vision

    # misc ---------------------------------------------------------------------
    emb_scale: bool = False  # multiply embeddings by sqrt(d_model) (gemma)
    embed_norm: bool = False  # LayerNorm right after embedding (RWKV ln0)
    max_positions: int = 32768  # learned position table size (pos_type=learned)
    tie_embeddings: bool = False
    remat: str = "full"  # full | dots | none
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # -------------------------------------------------------------------------
    def layer_types(self) -> tuple[str, ...]:
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def is_subquadratic(self) -> bool:
        """True when no layer needs an unbounded full-attention KV cache."""
        return all(t in ("swa", "rglru", "rwkv6") for t in self.layer_types())

    @property
    def q_dim(self) -> int:
        if self.use_mla:
            return self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
        return self.n_heads * self.head_dim

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    # parameter count (analytic; used for 6ND roofline + cost model) ----------
    def param_count(self) -> int:
        d = self.d_model
        n = 0
        n += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        layers = list(self.layer_types())
        if self.enc_dec:
            layers = ["attn"] * self.n_enc_layers + ["xattn"] * self.n_layers
        for i, t in enumerate(layers):
            if t in ("attn", "swa", "xattn"):
                n += self._attn_params()
                if t == "xattn":
                    n += self._attn_params()  # cross-attention
            elif t == "rglru":
                w = self.lru_width or d
                n += 2 * d * w + self.conv_width * w + 4 * w + w * d
            elif t == "rwkv6":
                h = d // self.rwkv_head_size
                n += 4 * d * d + d * self.rwkv_decay_lora * 2 + 5 * self.rwkv_mix_lora * d * 2
                n += 2 * h * self.rwkv_head_size  # u, per-head ln
            # mlp
            is_moe = self.n_experts > 0 and i >= self.first_dense_layers and t not in ("rwkv6",)
            if t == "rwkv6":
                n += 2 * d * self.d_ff + d * d  # channel mix: k, v, r
            elif is_moe:
                ff = self.d_ff_expert
                n += self.n_experts * 3 * d * ff
                n += self.n_shared_experts * 3 * d * ff
                n += d * self.n_experts  # router
            else:
                mult = 3 if self.mlp_type == "glu" else 2
                n += mult * d * self.d_ff
            n += 2 * d  # norms
        n += d  # final norm
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        if self.use_mla:
            n = 0
            if self.q_lora_rank:
                n += d * self.q_lora_rank + self.q_lora_rank * self.q_dim
            else:
                n += d * self.q_dim
            n += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            n += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
            n += self.n_heads * self.v_head_dim * d
            return n
        hd = self.head_dim
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable_shapes(cfg: ModelConfig) -> list[ShapeSpec]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.is_subquadratic:
            continue  # documented skip: full-attention arch
        out.append(s)
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs() -> list[str]:
    return sorted(_REGISTRY)
