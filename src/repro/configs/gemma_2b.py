"""gemma-2b [arXiv:2403.08295; hf:google/gemma-2b].

18L, d_model=2048, 8 heads with head_dim=256, MQA (1 KV head), GeGLU with
d_ff=16384, vocab=256000, sqrt(d)-scaled embeddings, (1+w) RMSNorm, tied
embeddings.  Pure full attention => long_500k skipped per assignment rule.
"""
from repro.configs.base import ModelConfig, register


@register("gemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        source="arXiv:2403.08295",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        mlp_type="glu",
        act="gelu",  # GeGLU
        pos_type="rope",
        gemma_norm=True,
        emb_scale=True,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=256, vocab_size=512, remat="none",
    )
