"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L, d_model=1024, 16 heads / 8 KV (GQA), MoE with 32 experts top-8,
expert d_ff=512, vocab=49155, SwiGLU, RoPE.  Small-MoE contrast point to
deepseek-v2 in the roofline table.
"""
from repro.configs.base import ModelConfig, register


@register("granite-moe-1b-a400m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab_size=49155,
        mlp_type="glu",
        act="silu",
        pos_type="rope",
        n_experts=32,
        top_k=8,
        n_shared_experts=0,
        d_ff_expert=512,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=256, n_experts=4, top_k=2, d_ff_expert=64,
        remat="none",
    )
