"""whisper-medium [arXiv:2212.04356] — encoder-decoder, audio frontend stub.

24L decoder (+24L encoder), d_model=1024, 16 heads (MHA), d_ff=4096,
vocab=51865.  The conv frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings of shape (batch, frames, d_model).
"""
from repro.configs.base import ModelConfig, register


@register("whisper-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        source="arXiv:2212.04356",
        n_layers=24,
        n_enc_layers=24,
        enc_dec=True,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51865,
        mlp_type="dense",
        act="gelu",
        norm_type="layernorm",
        norm_eps=1e-5,
        pos_type="learned",  # decoder learned positions; encoder sinusoidal
        frontend="audio",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256, remat="none",
    )
