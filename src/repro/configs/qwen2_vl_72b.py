"""qwen2-vl-72b [arXiv:2409.12191; hf:Qwen/Qwen2-VL-72B].

80L, d_model=8192, 64 heads / 8 KV (GQA), d_ff=29568, vocab=152064, SwiGLU,
**M-RoPE** (multimodal rotary: temporal/height/width sections 16/24/24 freq
pairs of the 128-dim head).  The vision frontend (dynamic-resolution ViT) is
a STUB per the assignment: ``input_specs`` provides precomputed patch
embeddings; position_ids carry the 3-component M-RoPE coordinates.
"""
from repro.configs.base import ModelConfig, register


@register("qwen2-vl-72b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        source="arXiv:2409.12191",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        mlp_type="glu",
        act="silu",
        pos_type="mrope",
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),
        frontend="vision",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256, mrope_sections=(2, 3, 3), remat="none",
    )
