"""recurrentgemma-9b [arXiv:2402.19427 (Griffin); hf:google/recurrentgemma-9b].

Hybrid: Griffin pattern (RG-LRU, RG-LRU, local-attn) cycling over 38 layers,
d_model=4096, 16 heads head_dim=256, MQA (kv=1) local attention with window
2048, GeGLU d_ff=12288, vocab=256000, lru_width=4096, conv1d width 4.
Sub-quadratic (bounded window + O(1) recurrent state) => long_500k runs.
"""
from repro.configs.base import ModelConfig, register


@register("recurrentgemma-9b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        source="arXiv:2402.19427",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        layer_pattern=("rglru", "rglru", "swa"),
        window=2048,
        mlp_type="glu",
        act="gelu",  # GeGLU
        pos_type="rope",
        gemma_norm=True,
        emb_scale=True,
        tie_embeddings=True,
        lru_width=4096,
        conv_width=4,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=256, window=16, lru_width=64, remat="none",
    )
