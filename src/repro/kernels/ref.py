"""Pure-jnp oracles for the Pallas kernels (small shapes; full materialization)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  scale: float | None = None, q_offset: int = 0) -> jax.Array:
    """q: (B,Sq,Hq,D); k/v: (B,Skv,Hkv,D).  Positions are contiguous:
    pos_q = q_offset + arange(Sq), pos_k = arange(Skv)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * scale
    pos_q = q_offset + jnp.arange(Sq)
    pos_k = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= pos_k[None, :] <= pos_q[:, None]
    if window > 0:
        mask &= (pos_q[:, None] - pos_k[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, v.shape[-1]).astype(q.dtype)


def wkv_ref(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
            u: jax.Array, s0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """RWKV-6 recurrence oracle.  All (B,S,H,N) f32; u (H,N); s0 (B,H,N,N).

    y_t = r_t . (S_{t-1} + (u*k_t) v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    def step(S, inp):
        rt, kt, vt, lwt = inp
        y = (jnp.einsum("bhn,bhnm->bhm", rt, S)
             + jnp.einsum("bhn,hn,bhn->bh", rt, u, kt)[..., None] * vt)
        S_new = jnp.exp(lwt)[..., None] * S + kt[..., None] * vt[:, :, None, :]
        return S_new, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, log_w))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_fin
