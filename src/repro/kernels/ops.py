"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the compiled kernels run natively; on CPU (this container) the same
kernel bodies execute in ``interpret=True`` mode for correctness work, and
model code falls back to the XLA reference path for anything
performance-shaped (the dry-run lowers the XLA path; see DESIGN.md §6).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import linear_scan as _ls
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "q_offset", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    q_offset=0, interpret=None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, q_offset=q_offset,
                               interpret=interp)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def linear_scan(r, k, v, log_w, u, s0, *, chunk=64, interpret=None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _ls.linear_scan(r, k, v, log_w, u, s0, chunk=chunk,
                           interpret=interp)


# re-exported oracles
attention_ref = _ref.attention_ref
wkv_ref = _ref.wkv_ref
