"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the compiled kernels run natively; on CPU (this container) the same
kernel bodies execute in ``interpret=True`` mode for correctness work, and
model code falls back to the XLA reference path for anything
performance-shaped (the dry-run lowers the XLA path; see DESIGN.md §6).

Tile selection (``kernels/autotune.py``) happens *outside* the jit boundary
so the blocks reach ``pallas_call`` as static values:

* explicit ``block_q=``/``block_k=``/``chunk=`` kwargs always win and never
  consult the tuner;
* ``tuned=True`` resolves the shape/dtype/backend key against the autotune
  cache — a hit (including entries shipped via the committed baseline store)
  costs zero timing work; a miss on a compiled-TPU host runs the timing
  search once and persists the winner; interpret mode, non-TPU hosts and
  in-trace calls fall back to the VMEM/head-dim heuristic instead of timing;
* ``tuned=False`` (default) keeps the fixed historical defaults.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import autotune as _at
from repro.kernels import flash_attention as _fa
from repro.kernels import linear_scan as _ls
from repro.kernels import ref as _ref

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
DEFAULT_CHUNK = 64


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _can_time(*arrays) -> bool:
    """Eager concrete arrays only: a timing search cannot run under trace."""
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "q_offset", "block_q", "block_k",
    "out_scale", "interpret"))
def _flash_jit(q, k, v, residual, *, causal, window, scale, q_offset,
               block_q, block_k, out_scale, interpret):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, scale=scale,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        out_scale=out_scale, residual=residual, interpret=interpret)


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    q_offset=0, block_q=None, block_k=None, tuned=False,
                    out_scale=1.0, residual=None, interpret=None):
    interp = (not _on_tpu()) if interpret is None else interpret
    bq, bk = block_q, block_k
    if tuned and (bq is None or bk is None):
        cfg = _resolve_attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, scale=scale,
                                 interpret=interp,
                                 has_residual=residual is not None)
        bq = bq if bq is not None else cfg["block_q"]
        bk = bk if bk is not None else cfg["block_k"]
    return _flash_jit(q, k, v, residual, causal=causal, window=window,
                      scale=scale, q_offset=q_offset,
                      block_q=bq if bq is not None else DEFAULT_BLOCK_Q,
                      block_k=bk if bk is not None else DEFAULT_BLOCK_K,
                      out_scale=out_scale, interpret=interp)


def _resolve_attention(q, k, v, *, causal, window, q_offset, scale,
                       interpret, has_residual):
    tuner = _at.get_tuner()
    key = _at.attention_key(q.shape, k.shape, v.shape, q.dtype,
                            causal=causal, window=window,
                            backend=_at.backend_tag(interpret))
    B, Sq, Hq, D = q.shape
    _, Skv, _, Dv = v.shape

    def heuristic():
        return _at.heuristic_attention(Sq, Skv, D, Dv, q.dtype)

    if _on_tpu() and not interpret and _can_time(q, k, v):
        hit = tuner.lookup(key)
        if hit is not None and hit.get("mode") != "heuristic":
            return hit["config"]
        cands = _at.attention_candidates(Sq, Skv, D, Dv, q.dtype,
                                         has_residual=has_residual)
        if not cands:
            return heuristic()

        def measure(cfg):
            return _at.measure_us(lambda: _flash_jit(
                q, k, v, residual=None, causal=causal, window=window,
                scale=scale, q_offset=q_offset, block_q=cfg["block_q"],
                block_k=cfg["block_k"], out_scale=1.0, interpret=False))

        return tuner.tune(key, cands, measure, mode="tpu")["config"]
    return tuner.resolve(key, heuristic)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _scan_jit(r, k, v, log_w, u, s0, *, chunk, interpret):
    return _ls.linear_scan(r, k, v, log_w, u, s0, chunk=chunk,
                           interpret=interpret)


def linear_scan(r, k, v, log_w, u, s0, *, chunk=None, tuned=False,
                interpret=None):
    interp = (not _on_tpu()) if interpret is None else interpret
    c = chunk
    if tuned and c is None:
        c = _resolve_scan(r, k, v, log_w, u, s0, interpret=interp)["chunk"]
    return _scan_jit(r, k, v, log_w, u, s0,
                     chunk=c if c is not None else DEFAULT_CHUNK,
                     interpret=interp)


def _resolve_scan(r, k, v, log_w, u, s0, *, interpret):
    tuner = _at.get_tuner()
    key = _at.scan_key(r.shape, r.dtype, backend=_at.backend_tag(interpret))
    B, S, H, N = r.shape

    def heuristic():
        return _at.heuristic_scan(S, N, r.dtype)

    if _on_tpu() and not interpret and _can_time(r, k, v, log_w, u, s0):
        hit = tuner.lookup(key)
        if hit is not None and hit.get("mode") != "heuristic":
            return hit["config"]
        cands = _at.scan_candidates(S, N, r.dtype)
        if not cands:
            return heuristic()

        def measure(cfg):
            return _at.measure_us(lambda: _scan_jit(
                r, k, v, log_w, u, s0, chunk=cfg["chunk"],
                interpret=False)[0])

        return tuner.tune(key, cands, measure, mode="tpu")["config"]
    return tuner.resolve(key, heuristic)


def paged_attention(q, kp, vp, posp, table, pos_q, *, causal=True, window=0,
                    scale=None):
    """Decode attention over a paged KV pool.

    q: (B, 1, Hq, Dk); kp/vp: (n_pages, page_size, Hkv, D) pools;
    posp: (n_pages, page_size) absolute positions (-1 = empty);
    table: (B, max_pages) block table, entries == n_pages = unallocated.

    Gathers each slot's pages into a contiguous (B, max_pages*page_size, ...)
    view — unallocated pages read as pos == -1 via take's fill mode, so the
    position mask in ``attention_core`` drops them exactly.  The gather is
    O(B * max_pages * page_size), i.e. per-slot *capacity*, not pool size:
    slots only ever pay for the pages their own request reserved.
    """
    import jax.numpy as jnp

    from repro.models.attention import attention_core  # lazy: avoid cycle

    B, P = table.shape
    ps = kp.shape[1]
    flat = table.reshape(-1)  # (B*P,)
    k = jnp.take(kp, flat, axis=0, mode="fill", fill_value=0)
    v = jnp.take(vp, flat, axis=0, mode="fill", fill_value=0)
    pos_k = jnp.take(posp, flat, axis=0, mode="fill", fill_value=-1)
    k = k.reshape(B, P * ps, *kp.shape[2:])
    v = v.reshape(B, P * ps, *vp.shape[2:])
    pos_k = pos_k.reshape(B, P * ps)
    return attention_core(q, k, v, pos_q, pos_k, causal=causal,
                          window=window, scale=scale)


# re-exported oracles
attention_ref = _ref.attention_ref
wkv_ref = _ref.wkv_ref
