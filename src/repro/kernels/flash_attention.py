"""Flash attention for TPU: fused streaming-softmax with BlockSpec VMEM tiling.

Adaptation notes (DESIGN.md §6): FlashAttention's GPU formulation (warps,
shared-memory tiles) is re-expressed for the TPU memory hierarchy — HBM ->
VMEM block copies driven by ``pl.BlockSpec`` index maps, (block_q x block_k)
score tiles shaped for the 128x128 MXU, and the online max/denominator carry
kept in VMEM scratch across the sequential kv grid dimension.  Causal and
sliding-window blocks that are fully masked are skipped via ``pl.when``
(the TPU grid is sequential in the innermost dimension, so the skip saves
real MXU cycles rather than relying on SM occupancy).

Supports GQA/MQA directly: kv blocks are indexed by q_head // group_size.
Positions are contiguous (pos_q = q_offset + iota, pos_k = iota) — the
train/prefill regime; decode uses the XLA path (attention.py), where the
work per step is tiny.

Arbitrary sequence lengths are supported: inputs are padded up to the next
block multiple and the output sliced back.  Padded key positions are masked
inside the kernel via ``kv_len`` (for causal attention with the standard
``q_offset = Skv - Sq`` continuation layout the causal mask already excludes
them, but the explicit mask keeps bidirectional and window variants correct
too).  When the shapes already divide the blocks, the raw unpadded path runs
unchanged.

The epilogue (``out_scale`` multiply + ``residual`` add) is fused into the
final kv step's ``_finish`` so the scaled/residual-added output leaves VMEM
exactly once instead of costing an extra HBM round trip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _flash_kernel(q_ref, k_ref, v_ref, *rest, scale: float, causal: bool,
                  window: int, q_offset: int, kv_len: int, block_q: int,
                  block_k: int, n_kv_blocks: int, out_scale: float,
                  has_residual: bool):
    if has_residual:
        res_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        res_ref = None
        o_ref, acc_ref, m_ref, l_ref = rest
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = q_offset + iq * block_q
    k_start = ik * block_k
    masked = causal or window > 0 or kv_len > 0

    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        if masked:
            pos_q = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            pos_k = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            mask = jnp.ones_like(s, dtype=jnp.bool_)
            if causal:
                mask &= pos_k <= pos_q
            if window > 0:
                mask &= (pos_q - pos_k) < window
            if kv_len > 0:  # padded keys beyond the true length
                mask &= pos_k < kv_len
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    if masked:
        # Block-level skip: entirely-future (causal), stale (window) or
        # fully-padded (kv_len) tiles.
        should = jnp.bool_(True)
        if causal:
            should &= q_start + block_q - 1 >= k_start
        if window > 0:
            should &= q_start - (k_start + block_k - 1) < window
        if kv_len > 0:
            should &= k_start < kv_len
        pl.when(should)(_compute)
    else:
        _compute()

    @pl.when(ik == n_kv_blocks - 1)
    def _finish():
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o = acc_ref[...] / l[:, None]
        if out_scale != 1.0:
            o = o * out_scale
        if res_ref is not None:
            o = o + res_ref[0, :, 0, :].astype(jnp.float32)
        o_ref[0, :, 0, :] = o.astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    out_scale: float = 1.0,
    residual: jax.Array | None = None,  # (B, Sq, Hq, Dv), fused epilogue add
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)

    # pad-to-block / slice-back: arbitrary sequence lengths run through the
    # same kernel; the raw path below is untouched when shapes divide
    pad_q = -Sq % block_q
    pad_k = -Skv % block_k
    kv_len = Skv if pad_k else 0
    if pad_q or pad_k:
        if pad_q:
            q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
            if residual is not None:
                residual = jnp.pad(residual,
                                   ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        if pad_k:
            k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        Sq_p, Skv_p = Sq + pad_q, Skv + pad_k
    else:
        Sq_p, Skv_p = Sq, Skv
    nq, nk = Sq_p // block_q, Skv_p // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, kv_len=kv_len, block_q=block_q, block_k=block_k,
        n_kv_blocks=nk, out_scale=out_scale,
        has_residual=residual is not None)

    in_specs = [
        pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
        pl.BlockSpec((1, block_k, 1, D), lambda b, h, iq, ik: (b, ik, h // G, 0)),
        pl.BlockSpec((1, block_k, 1, Dv), lambda b, h, iq, ik: (b, ik, h // G, 0)),
    ]
    operands = [q, k, v]
    if residual is not None:
        in_specs.append(pl.BlockSpec((1, block_q, 1, Dv),
                                     lambda b, h, iq, ik: (b, iq, h, 0)))
        operands.append(residual)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_q, 1, Dv), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq_p, Hq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dv), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),  # row-max, lane-broadcast
            pltpu.VMEM((block_q, 128), jnp.float32),  # row-sum, lane-broadcast
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    if pad_q:
        out = out[:, :Sq]
    return out
