"""Shape-keyed autotuner for the Pallas hot paths.

The flash-attention and WKV linear-scan kernels take tile sizes
(``block_q``/``block_k``, ``chunk``) that used to be fixed at one default
across every model config.  The right tile depends on the sequence length,
head dim, dtype and backend — so this module searches the tile space per
*kernel key* (kernel name + shape signature + dtype + backend) and memoizes
the winner:

* **Candidates** are generated from the power-of-two tile ladder, then
  validated for block-divisibility (after the entry point's clamp-to-S) and
  VMEM fit (double-buffered input blocks + scratch + score tile against the
  per-core budget) *before* any timing work.
* **Timing** wraps each candidate call in ``jax.block_until_ready`` with a
  compile/warmup call first and best-of-N wall-clock after — dispatch queues
  never leak into the numbers.
* **Memoization** is two-level: an in-process dict (a cache hit does zero
  timing work — asserted by tests) backed by a persistent JSON store.  The
  store merges the committed baseline (``benchmarks/baselines/
  autotune_cache.json`` — tuned configs ride along to CI machines) with the
  local writable cache (``artifacts/autotune_cache.json``); local entries
  win.
* **Fallback**: interpret mode and non-TPU hosts never trigger a timing
  search at dispatch time — a cache miss there resolves to a heuristic
  default keyed off the head dim and the VMEM budget.  Explicit
  ``block_q=``/``chunk=`` kwargs at the entry points bypass the tuner
  entirely (``kernels/ops.py``).

``benchmarks/kernel_bench.py`` drives eager tuning over the model-config
sweep and commits the results; ``benchmarks/check_kernel_regression.py``
fails CI when a config's tuned/default ratio regresses.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Iterable, Sequence

import jax

#: per-core VMEM on current TPUs (v4/v5e: ~16 MB); the budget leaves head
#: room for the compiler's own spills
VMEM_BYTES = 16 * 1024 * 1024
VMEM_BUDGET = int(0.8 * VMEM_BYTES)

#: power-of-two tile ladder the search walks
ATTN_BLOCKS = (32, 64, 128, 256, 512)
SCAN_CHUNKS = (16, 32, 64, 128, 256)

DEFAULT_CACHE_PATH = os.path.join("artifacts", "autotune_cache.json")
BASELINE_CACHE_PATH = os.path.join("benchmarks", "baselines",
                                   "autotune_cache.json")


def _dtype_bytes(dtype) -> int:
    import jax.numpy as jnp
    return jnp.dtype(dtype).itemsize


def _dtype_name(dtype) -> str:
    import jax.numpy as jnp
    return jnp.dtype(dtype).name


def backend_tag(interpret: bool = False) -> str:
    """Cache-key backend tag; interpret mode tunes a different machine (the
    Pallas interpreter) than compiled TPU execution, so it keys separately."""
    base = jax.default_backend()
    return f"{base}+interp" if interpret else base


# ---------------------------------------------------------------------------
# keys
# ---------------------------------------------------------------------------


def attention_key(q_shape: Sequence[int], k_shape: Sequence[int],
                  v_shape: Sequence[int], dtype, *, causal: bool,
                  window: int, backend: str) -> str:
    B, Sq, Hq, D = q_shape
    _, Skv, Hkv, _ = k_shape
    Dv = v_shape[-1]
    return ("flash_attention|" + backend + "|" + _dtype_name(dtype)
            + f"|B{B}|Sq{Sq}|Skv{Skv}|Hq{Hq}|Hkv{Hkv}|D{D}|Dv{Dv}"
            + f"|c{int(causal)}|w{window}")


def scan_key(r_shape: Sequence[int], dtype, *, backend: str) -> str:
    B, S, H, N = r_shape
    return ("linear_scan|" + backend + "|" + _dtype_name(dtype)
            + f"|B{B}|S{S}|H{H}|N{N}")


# ---------------------------------------------------------------------------
# candidate generation: divisibility + VMEM-fit validation (no timing)
# ---------------------------------------------------------------------------


def attention_vmem_bytes(block_q: int, block_k: int, D: int, Dv: int,
                         dtype, has_residual: bool = False) -> int:
    """VMEM footprint model for one (block_q x block_k) flash tile: double-
    buffered input/output blocks at the IO dtype, f32 scratch (acc + the
    lane-broadcast m/l carries) and the f32 score/probability tile."""
    io = _dtype_bytes(dtype)
    inputs = block_q * D + 2 * block_k * max(D, Dv)  # q + k + v blocks
    if has_residual:
        inputs += block_q * Dv
    out = block_q * Dv
    scratch = 4 * (block_q * Dv + 2 * block_q * 128)
    score = 2 * 4 * block_q * block_k  # s and p tiles, f32
    return 2 * io * (inputs + out) + scratch + score


def scan_vmem_bytes(chunk: int, N: int, dtype) -> int:
    """VMEM model for one WKV chunk: 4 double-buffered (chunk x N) sequence
    blocks, the (N x N) state scratch, and the dominant (C, C, N) f32
    intra-chunk decay tensor."""
    io = _dtype_bytes(dtype)
    seq = 4 * chunk * N + chunk * N  # r/k/v/lw in + y out
    state = 2 * N * N
    intra = 4 * (chunk * chunk * N + chunk * chunk)  # d tensor + (C,C) tile
    return 2 * io * seq + 4 * state + intra


@dataclasses.dataclass(frozen=True)
class AttnCandidate:
    block_q: int
    block_k: int

    def as_dict(self) -> dict:
        return {"block_q": self.block_q, "block_k": self.block_k}


@dataclasses.dataclass(frozen=True)
class ScanCandidate:
    chunk: int

    def as_dict(self) -> dict:
        return {"chunk": self.chunk}


def attention_candidates(Sq: int, Skv: int, D: int, Dv: int, dtype,
                         *, blocks: Iterable[int] = ATTN_BLOCKS,
                         vmem_budget: int = VMEM_BUDGET,
                         has_residual: bool = False) -> list[AttnCandidate]:
    """Validated (block_q, block_k) pairs: clamped to the sequence, dividing
    it exactly (the entry point pads otherwise — tuning keys on the padded
    shape), and fitting the VMEM budget."""
    out: list[AttnCandidate] = []
    seen: set[tuple[int, int]] = set()
    for bq in blocks:
        ebq = min(bq, Sq)
        if Sq % ebq:
            continue
        for bk in blocks:
            ebk = min(bk, Skv)
            if Skv % ebk or (ebq, ebk) in seen:
                continue
            if attention_vmem_bytes(ebq, ebk, D, Dv, dtype,
                                    has_residual) > vmem_budget:
                continue
            seen.add((ebq, ebk))
            out.append(AttnCandidate(ebq, ebk))
    return out


def scan_candidates(S: int, N: int, dtype,
                    *, chunks: Iterable[int] = SCAN_CHUNKS,
                    vmem_budget: int = VMEM_BUDGET) -> list[ScanCandidate]:
    out: list[ScanCandidate] = []
    seen: set[int] = set()
    for c in chunks:
        ec = min(c, S)
        if S % ec or ec in seen:
            continue
        if scan_vmem_bytes(ec, N, dtype) > vmem_budget:
            continue
        seen.add(ec)
        out.append(ScanCandidate(ec))
    return out


# ---------------------------------------------------------------------------
# heuristic defaults (zero timing; used on non-TPU hosts / interpret misses)
# ---------------------------------------------------------------------------


def heuristic_attention(Sq: int, Skv: int, D: int, Dv: int, dtype,
                        *, vmem_budget: int = VMEM_BUDGET) -> dict:
    """Largest MXU-aligned tile that fits the VMEM budget, keyed off the head
    dim: small heads leave VMEM for longer q tiles, 256-wide heads (gemma)
    need narrower ones."""
    want_q = 256 if D <= 64 else (128 if D <= 128 else 64)
    want_k = 128 if D <= 128 else 64
    cands = attention_candidates(Sq, Skv, D, Dv, dtype,
                                 vmem_budget=vmem_budget)
    if not cands:  # budget too tight for any ladder tile: minimal blocks
        return {"block_q": min(32, Sq), "block_k": min(32, Skv)}
    # closest to the target, preferring the larger tile on ties
    best = min(cands, key=lambda c: (abs(c.block_q - min(want_q, Sq))
                                     + abs(c.block_k - min(want_k, Skv)),
                                     -c.block_q, -c.block_k))
    return best.as_dict()


def heuristic_scan(S: int, N: int, dtype,
                   *, vmem_budget: int = VMEM_BUDGET) -> dict:
    """Largest chunk whose (C, C, N) intra-chunk tensor fits the budget;
    N = 64 heads land on the classic chunk = 64."""
    want = 64 if N <= 64 else 32
    cands = scan_candidates(S, N, dtype, vmem_budget=vmem_budget)
    if not cands:
        return {"chunk": min(16, S)}
    best = min(cands, key=lambda c: (abs(c.chunk - min(want, S)), -c.chunk))
    return best.as_dict()


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------


def measure_us(fn: Callable[[], jax.Array], *, iters: int = 3,
               warmup: int = 1) -> float:
    """Best-of-``iters`` wall-clock microseconds for ``fn()``, with
    ``block_until_ready`` inside every timed window (async dispatch never
    hides kernel time) and ``warmup`` untimed calls first (compile)."""
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------


class Autotuner:
    """Two-level (in-process + persistent JSON) tile cache with search.

    ``timing_calls`` counts candidate measurements — tests assert it stays 0
    on cache hits; ``tune`` is the only method that times anything.
    """

    def __init__(self, cache_path: str | None = None,
                 baseline_path: str | None = None):
        self.cache_path = cache_path if cache_path is not None else \
            os.environ.get("REPRO_AUTOTUNE_CACHE", DEFAULT_CACHE_PATH)
        self.baseline_path = baseline_path if baseline_path is not None \
            else BASELINE_CACHE_PATH
        self._mem: dict[str, dict] = {}
        self._loaded = False
        self.timing_calls = 0

    # ------------------------------------------------------------ storage
    def _load(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        for path in (self.baseline_path, self.cache_path):  # local wins
            if not path or not os.path.exists(path):
                continue
            try:
                with open(path) as f:
                    data = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            for key, entry in data.get("entries", {}).items():
                if isinstance(entry, dict) and "config" in entry:
                    self._mem[key] = entry

    def _persist(self) -> None:
        if not self.cache_path:
            return
        os.makedirs(os.path.dirname(self.cache_path) or ".", exist_ok=True)
        # merge-on-write so concurrent processes lose nothing but races
        entries: dict[str, dict] = {}
        if os.path.exists(self.cache_path):
            try:
                with open(self.cache_path) as f:
                    entries = json.load(f).get("entries", {})
            except (OSError, json.JSONDecodeError):
                entries = {}
        entries.update({k: v for k, v in self._mem.items()
                        if v.get("mode") != "heuristic"})
        tmp = self.cache_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": 1, "entries": entries}, f, indent=1,
                      sort_keys=True)
        os.replace(tmp, self.cache_path)

    # ------------------------------------------------------------- lookup
    def lookup(self, key: str) -> dict | None:
        """Cached entry for ``key`` or None.  Never times anything."""
        self._load()
        return self._mem.get(key)

    def put(self, key: str, entry: dict, *, persist: bool = True) -> None:
        self._mem[key] = entry
        if persist and entry.get("mode") != "heuristic":
            self._persist()

    def resolve(self, key: str, heuristic: Callable[[], dict]) -> dict:
        """Cache hit or heuristic default — the dispatch-time path; zero
        timing work by construction.  Heuristic entries stay in-process only
        (a later real ``tune`` overrides them)."""
        hit = self.lookup(key)
        if hit is not None:
            return hit["config"]
        cfg = heuristic()
        self._mem[key] = {"config": cfg, "mode": "heuristic"}
        return cfg

    # -------------------------------------------------------------- search
    def tune(self, key: str,
             candidates: Sequence[AttnCandidate | ScanCandidate],
             measure: Callable[[dict], float], *, mode: str,
             persist: bool = True, force: bool = False) -> dict:
        """Search ``candidates`` with ``measure(config) -> us`` and memoize
        the winner.  A prior *timed* entry for ``key`` is returned as-is —
        zero timing work on a hit; a heuristic placeholder is re-tuned.
        ``force=True`` re-times even on a hit (the benchmarks use it so a
        shipped baseline never mixes with timings from a different machine)."""
        hit = self.lookup(key)
        if hit is not None and hit.get("mode") != "heuristic" and not force:
            return hit
        if not candidates:
            raise ValueError(f"no valid tile candidates for {key}")
        timed: list[tuple[float, dict]] = []
        for cand in candidates:
            cfg = cand.as_dict()
            self.timing_calls += 1
            timed.append((measure(cfg), cfg))
        best_us, best_cfg = min(timed, key=lambda t: t[0])
        entry = {
            "config": best_cfg,
            "us": round(best_us, 2),
            "mode": mode,
            "candidates": {json.dumps(c, sort_keys=True): round(us, 2)
                           for us, c in timed},
        }
        self.put(key, entry, persist=persist)
        return entry


_TUNER: Autotuner | None = None


def get_tuner() -> Autotuner:
    global _TUNER
    if _TUNER is None:
        _TUNER = Autotuner()
    return _TUNER


def reset_tuner() -> None:
    """Drop the process-global tuner (tests)."""
    global _TUNER
    _TUNER = None
