"""Pallas TPU kernels for the compute hot spots (flash attention, RWKV WKV
scan), their pure-jnp oracles (``ref``), the jit'd dispatch layer (``ops``)
and the shape-keyed tile autotuner (``autotune``)."""
from repro.kernels import autotune, ops  # noqa: F401
