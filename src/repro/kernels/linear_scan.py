"""Chunked gated-linear-recurrence kernel (RWKV-6 WKV) for TPU.

The GPU formulations (RWKV CUDA, GLA fused chunk) rely on warp-level
parallelism over heads; the TPU-native shape is: one (batch, head) per
parallel grid cell, the chunk dimension sequential ("arbitrary"), the
running (N x N) state held in VMEM scratch across chunks, and the intra-chunk
part expressed as (C x C) tiles that feed the MXU.  Stability: all decay
algebra happens in log space; every exp() argument is <= 0 by construction.

  y_t = r_t . (S_{t-1} + (u*k_t) v_t^T);   S_t = diag(w_t) S_{t-1} + k_t v_t^T

The u-bonus term is fused into the intra-chunk tile's diagonal (d[t,t,:] = u)
instead of being recomputed as a separate (C,) reduction plus a rank-1 add:
the single (C x C) @ (C x N) MXU matmul then carries both the strict-lower
intra-chunk part and the bonus in one pass.

Arbitrary sequence lengths are supported by zero-padding up to the chunk
multiple: padded steps carry log_w = 0 (decay 1) and k = 0, so the running
state — and therefore ``s_fin`` — passes through them unchanged; the padded
``y`` rows are sliced away.  Shapes that already divide run the raw path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, s_out_ref,
                s_scr, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)  # (C, N)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    lw = lw_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :].astype(jnp.float32)  # (N,)
    S = s_scr[...]

    p = jnp.cumsum(lw, axis=0)  # inclusive log-decay, <= 0
    p_prev = p - lw  # exclusive (through t-1)

    y_inter = jax.lax.dot_general(r * jnp.exp(p_prev), S,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # intra-chunk attention-like tile, bonus fused on the diagonal:
    #   A[t,s] = sum_n r[t,n] k[s,n] e^{p_prev[t,n]-p[s,n]}   (s < t)
    #   A[t,t] = sum_n r[t,n] k[t,n] u[n]                     (u-bonus)
    diff = p_prev[:, None, :] - p[None, :, :]  # (C, C, N), masked to s<t
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    d = jnp.where((row > col)[:, :, None],
                  jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    d = jnp.where((row == col)[:, :, None], u[None, None, :], d)
    a = jnp.sum(r[:, None, :] * k[None, :, :] * d, axis=-1)  # (C, C)
    y = y_inter + jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    k_hat = k * jnp.exp(p[-1:, :] - p)
    s_new = (jnp.exp(p[-1])[:, None] * S
             + jax.lax.dot_general(k_hat, v, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    s_scr[...] = s_new

    @pl.when(ic == n_chunks - 1)
    def _finish():
        s_out_ref[0, 0] = s_new.astype(s_out_ref.dtype)


def linear_scan(
    r: jax.Array,  # (B, S, H, N) f32
    k: jax.Array,
    v: jax.Array,
    log_w: jax.Array,  # (B, S, H, N) f32, <= 0
    u: jax.Array,  # (H, N)
    s0: jax.Array,  # (B, H, N, N) f32
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, S, H, N = r.shape
    chunk = min(chunk, S)

    # pad-to-chunk / slice-back: zeros in (r, k, v) and log_w = 0 leave the
    # recurrence state untouched, so s_fin stays exact
    pad = -S % chunk
    if pad:
        seq_pad = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, seq_pad)
        k = jnp.pad(k, seq_pad)
        v = jnp.pad(v, seq_pad)
        log_w = jnp.pad(log_w, seq_pad)
    S_p = S + pad
    nc = S_p // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=nc)
    seq_spec = pl.BlockSpec((1, chunk, 1, N), lambda b, h, ic: (b, ic, h, 0))
    y, s_fin = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, N), lambda b, h, ic: (h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, N, N), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S_p, H, N), r.dtype),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, log_w, u, s0)
    if pad:
        y = y[:, :S]
    return y, s_fin
