"""The paper's contribution: cost-effective multi-platform orchestration."""
from repro.core.adaptive import (AdaptiveConfig, AdaptiveController,  # noqa: F401
                                 CircuitBreaker, DriftDetector,
                                 OnlineCostModel)
from repro.core.assets import (AssetGraph, AssetSpec, ComputeProfile,  # noqa: F401
                               RetryPolicy, asset)
from repro.core.clients import (JobSpec, LocalClient, PlatformClient,  # noqa: F401
                                PlatformError, SimulatedClusterClient)
from repro.core.context import ContextInjector, RunContext  # noqa: F401
from repro.core.coordinator import RunCoordinator, RunReport  # noqa: F401
from repro.core.costmodel import CostEstimate, CostModel  # noqa: F401
from repro.core.factory import DynamicClientFactory, Objective  # noqa: F401
from repro.core.faults import (ClientFaults, CoordinatorKilled,  # noqa: F401
                               FaultPlan)
from repro.core.journal import (JournalCorruption, JournalState,  # noqa: F401
                                RunJournal)
from repro.core.partitions import (MultiPartitions, PartitionsDefinition,  # noqa: F401
                                   StaticPartitions, TimeWindowPartitions,
                                   dep_partition_keys)
from repro.core.planner import (PlannedChoice, RunPlan, RunPlanner,  # noqa: F401
                                plan_run)
from repro.core.platforms import Platform, default_catalog  # noqa: F401
from repro.core.schedule import (ScheduleEngine, SlotConfig,  # noqa: F401
                                 SlotSchedule, task_dag)
from repro.core.selection import AssetSelection  # noqa: F401
from repro.core.store import (MaterializationStore, Staleness,  # noqa: F401
                              StoreCorruption, code_version,
                              resolve_staleness, source_hash)
from repro.core.telemetry import Event, MessageReader  # noqa: F401
