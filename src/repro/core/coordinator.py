"""Run Coordinator: DAG scheduling with retries, platform failover,
speculative straggler re-execution and elastic per-platform concurrency.

Failure semantics mirror the paper's operational reality (Fig 3): failed
attempts still bill (EMR burning money on flaky runs is why the mixed policy
wins), preemptions are distinguished from hard failures, and after
``retry.failover_after`` attempts on one platform the Dynamic Factory is
re-consulted with that platform deny-listed — the orchestration-level answer
to "EMR needs continual oversight".

Incremental materialization: before scheduling, staleness is resolved per
(asset, partition) against the content-addressed ``MaterializationStore``
(see store.py) and emitted as ``STALE`` telemetry; at launch time each task
re-checks its fingerprint against the now-materialized upstream data hashes,
so a warm cache executes zero tasks and an upstream that reproduces
byte-identical data cuts its downstream cone off early.  ``force=True``
rebuilds the selection unconditionally.
"""
from __future__ import annotations

import dataclasses
import time
import uuid
from typing import Any

from repro.core.adaptive import AdaptiveConfig, AdaptiveController
from repro.core.assets import AssetGraph, AssetSpec
from repro.core.clients import JobSpec, PlatformError, RunHandle
from repro.core.context import ContextInjector
from repro.core.costmodel import CostEstimate
from repro.core.factory import DynamicClientFactory, Objective
from repro.core.faults import FaultPlan
from repro.core.journal import JournalState, RunJournal
from repro.core.partitions import dep_partition_keys, partition_keys
from repro.core.planner import RunPlan, RunPlanner
from repro.core.schedule import ScheduleEngine, SlotConfig, task_dag
from repro.core.selection import AssetSelection
from repro.core.store import (MaterializationStore, code_version,
                              resolve_staleness)
from repro.core.telemetry import MessageReader


@dataclasses.dataclass
class AttemptRecord:
    platform: str
    status: str  # success | failure | preemption | cancelled
    sim_duration_s: float
    cost_usd: float
    speculative: bool = False
    error: str = ""


@dataclasses.dataclass
class TaskRecord:
    asset: str
    partition: str
    attempts: list[AttemptRecord] = dataclasses.field(default_factory=list)
    status: str = "pending"
    cached: bool = False

    @property
    def platform(self) -> str:
        return self.attempts[-1].platform if self.attempts else ""

    @property
    def total_sim_s(self) -> float:
        return sum(a.sim_duration_s for a in self.attempts)

    @property
    def serial_sim_s(self) -> float:
        """Wall-clock the task occupied its slot chain: retries serialize,
        but a speculative twin that *lost* ran concurrently with the primary
        and must not be double-counted (a twin that won is the attempt the
        task finished on, so it stays)."""
        return sum(a.sim_duration_s for a in self.attempts
                   if not (a.speculative and a.status != "success"))

    @property
    def total_cost(self) -> float:
        return sum(a.cost_usd for a in self.attempts)


@dataclasses.dataclass
class RunReport:
    run_id: str
    records: list[TaskRecord]
    graph: AssetGraph

    @property
    def ok(self) -> bool:
        return all(r.status == "success" for r in self.records)

    @property
    def total_cost(self) -> float:
        return sum(r.total_cost for r in self.records)

    def slot_makespan_s(self, slots: SlotConfig | None = None) -> float:
        """Slot-aware simulated makespan: replay the recorded attempt
        durations (retries serialize within a task) through the same
        finite-capacity list scheduler the planner predicts with, on the
        platform each task actually ran on.  This is the number a planner
        prediction should match under contention."""
        if not self.records:
            return 0.0
        recs = {(r.asset, r.partition): r for r in self.records}
        keys, preds = task_dag(self.graph,
                               sorted({r.asset for r in self.records}))
        keys = [k for k in keys if k in recs]
        engine = ScheduleEngine(
            keys, {k: [p for p in preds[k] if p in recs] for k in keys},
            slots)
        engine.load([recs[k].serial_sim_s for k in keys],
                    [recs[k].platform or "local" for k in keys])
        return engine.slot_schedule().makespan_s if slots is not None \
            else engine.makespan_s

    def makespan_s(self) -> float:
        """Critical-path simulated duration through the (asset, partition) DAG."""
        finish: dict[tuple[str, str], float] = {}
        by_asset: dict[str, list[TaskRecord]] = {}
        for r in self.records:
            by_asset.setdefault(r.asset, []).append(r)
        for name in self.graph.topo_order([r.asset for r in self.records]):
            spec = self.graph[name]
            for r in by_asset.get(name, []):
                dep_done = 0.0
                for d in spec.deps:
                    for dr in by_asset.get(d, []):
                        if dr.partition in (r.partition, "__all__") or \
                                r.partition == "__all__":
                            dep_done = max(dep_done,
                                           finish.get((d, dr.partition), 0.0))
                finish[(name, r.partition)] = dep_done + r.total_sim_s
        return max(finish.values()) if finish else 0.0

    def by_asset_cost(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for r in self.records:
            out[r.asset] = out.get(r.asset, 0.0) + r.total_cost
        return out

    def summary(self) -> str:
        lines = [f"run {self.run_id}: {len(self.records)} tasks, "
                 f"cost ${self.total_cost:.2f}, "
                 f"makespan {self.makespan_s() / 3600.0:.2f} h, ok={self.ok}"]
        for r in self.records:
            lines.append(
                f"  {r.asset}[{r.partition}] -> {r.platform} "
                f"({len(r.attempts)} attempts, {r.status}"
                f"{', cached' if r.cached else ''}) "
                f"${r.total_cost:.2f} / {r.total_sim_s / 3600.0:.3f} h")
        return "\n".join(lines)


@dataclasses.dataclass
class _Task:
    spec: AssetSpec
    partition: str
    record: TaskRecord
    attempt: int = 0
    deny: set[str] = dataclasses.field(default_factory=set)
    handle: RunHandle | None = None
    spec_handle: RunHandle | None = None  # speculative duplicate
    speculated: bool = False  # at most one speculative twin per attempt
    estimate: CostEstimate | None = None
    spec_estimate: CostEstimate | None = None
    launched_at: float = 0.0
    next_eligible: float = 0.0
    fingerprint: str = ""
    code_version: str = ""
    upstream: dict[str, str] = dataclasses.field(default_factory=dict)
    #: resume: attempt -> platform the crashed run had launched it on, so
    #: re-execution replays the same (run_id, asset, partition, attempt,
    #: platform) key — deterministic clients then reproduce the attempt
    replay: dict[int, str] = dataclasses.field(default_factory=dict)


class RunCoordinator:
    def __init__(self, graph: AssetGraph, factory: DynamicClientFactory,
                 store: MaterializationStore | None = None,
                 reader: MessageReader | None = None,
                 injector: ContextInjector | None = None,
                 max_concurrent: int = 8,
                 platform_slots: int = 2,
                 elastic_max_slots: int = 8,
                 straggler_factor: float = 2.5,
                 straggler_min_s: float = 0.05,
                 enable_speculation: bool = True,
                 use_cache: bool = True,
                 slots: SlotConfig | None = None,
                 adaptive: "AdaptiveController | AdaptiveConfig | bool | None"
                 = None,
                 journal_dir: str | None = None,
                 faults: FaultPlan | None = None):
        graph.validate()
        self.graph = graph
        self.factory = factory
        self.store = store if store is not None else MaterializationStore()
        self.reader = reader or MessageReader()
        self.injector = injector or ContextInjector(reader=self.reader)
        self.injector.reader = self.reader
        # one slot configuration drives both execution (this class) and the
        # planner's finite-capacity schedule, so plan and run agree on what
        # a slot is; ``slots`` wins over the legacy per-field kwargs
        self.slots = slots or SlotConfig(max_concurrent=max_concurrent,
                                         platform_slots=platform_slots,
                                         elastic_max_slots=elastic_max_slots)
        self.straggler_factor = straggler_factor
        self.straggler_min_s = straggler_min_s
        self.enable_speculation = enable_speculation
        self.use_cache = use_cache
        # closed-loop adaptation (see core/adaptive.py): online cost model +
        # drift-triggered replanning + per-platform circuit breakers.  Pass
        # True (defaults), an AdaptiveConfig, or a prebuilt controller.
        if adaptive is True:
            adaptive = AdaptiveConfig()
        if isinstance(adaptive, AdaptiveConfig):
            adaptive = AdaptiveController(factory.catalog, factory.cost_model,
                                          adaptive)
        self.adaptive: AdaptiveController | None = adaptive or None
        self._dep_key_cache: dict[tuple[str, str], list[str]] = {}
        # crash consistency (see core/journal.py): with ``journal_dir`` set,
        # every task lifecycle transition is fsync'd to an append-only
        # write-ahead log and ``resume(run_id)`` can reopen a killed run.
        # ``faults`` threads a seeded FaultPlan through the journal's
        # record-boundary kill points (chaos testing).
        self.journal_dir = journal_dir
        self.faults = faults
        self._jrnl: RunJournal | None = None
        # (asset, partition, attempt) -> (sim_s, cost_usd) for attempts the
        # crashed run already billed but whose output never landed: the
        # resumed re-execution carries the journaled bill instead of
        # emitting a second one (exactly-once billing)
        self._prepaid: dict[tuple[str, str, int], tuple[float, float]] = {}

    # legacy attribute style stays writable, but reads/writes go through
    # self.slots so the launch loop and plan() can never disagree
    @property
    def max_concurrent(self) -> int:
        return self.slots.max_concurrent

    @max_concurrent.setter
    def max_concurrent(self, v: int) -> None:
        self.slots = dataclasses.replace(self.slots, max_concurrent=v)

    @property
    def platform_slots(self) -> int:
        return self.slots.platform_slots

    @platform_slots.setter
    def platform_slots(self, v: int) -> None:
        self.slots = dataclasses.replace(self.slots, platform_slots=v)

    @property
    def elastic_max_slots(self) -> int:
        return self.slots.elastic_max_slots

    @elastic_max_slots.setter
    def elastic_max_slots(self, v: int) -> None:
        self.slots = dataclasses.replace(self.slots, elastic_max_slots=v)

    # ------------------------------------------------------------------ api
    def plan(self, targets: "AssetSelection | str | list[str] | None" = None,
             objective=None, force: bool = False) -> RunPlan:
        """Global cost/deadline-aware platform assignment (see planner.py),
        predicted under this coordinator's own slot configuration and —
        when caching is enabled — against this coordinator's store, so
        fresh tasks are priced at ~0 and kept out of the slot schedule.

        With an adaptive controller attached, pricing goes through the
        online cost model (learned duration ratios and success rates),
        open-breaker platforms are dropped from the candidate catalog, and
        scheduling is preemption-aware (rework-inflated durations)."""
        store = self.store if self.use_cache else None
        factory, preemption_aware = self.factory, False
        if self.adaptive is not None:
            factory = self.adaptive.planning_factory(self.factory,
                                                     time.time())
            preemption_aware = True
        return RunPlanner(self.graph, factory, slots=self.slots,
                          store=store,
                          preemption_aware=preemption_aware).plan(
                              targets, objective, force=force)

    def materialize(self,
                    targets: "AssetSelection | str | list[str] | None" = None,
                    run_id: str | None = None,
                    plan: RunPlan | None = None,
                    force: bool = False,
                    _prior: JournalState | None = None) -> RunReport:
        """Execute the target selection.  ``targets`` accepts an
        ``AssetSelection``, a CLI selection string, the legacy ``list[str]``
        or ``None`` (everything); upstream deps are always materialized (or
        served from cache) as needed.  ``force`` bypasses the cache and
        rebuilds every selected task.  ``_prior`` is internal: the replayed
        journal state ``resume`` reconciles a crashed run from."""
        if plan is not None and not plan.feasible:
            raise ValueError(f"refusing to execute infeasible plan: "
                             f"{plan.reason}")
        run_id = run_id or uuid.uuid4().hex[:10]
        # the run-level objective replans are budgeted against (replanned
        # objectives hold *remaining* budget/deadline, so always derive from
        # this one, never from the current plan's)
        base_obj = plan.objective if plan is not None else self.factory.objective
        names = AssetSelection.coerce(targets).resolve(self.graph)
        order = self.graph.topo_order(names)
        tasks: dict[tuple[str, str], _Task] = {}
        records: list[TaskRecord] = []
        for name in order:
            spec = self.graph[name]
            for key in partition_keys(spec.partitions):
                rec = TaskRecord(asset=name, partition=key)
                records.append(rec)
                tasks[(name, key)] = _Task(spec=spec, partition=key, record=rec)

        # write-ahead journal: BEGIN (fresh run) / RESUME (reopened run)
        # is durable before any task is touched
        self._prepaid = {}
        jrnl = self._jrnl = (
            RunJournal(self.journal_dir, run_id, faults=self.faults)
            if self.journal_dir is not None else None)
        if jrnl is not None:
            if _prior is None:
                jrnl.append(
                    "BEGIN", targets=names, force=force,
                    planned=plan is not None, use_cache=self.use_cache,
                    adaptive=self.adaptive is not None,
                    objective={
                        "name": base_obj.name,
                        "time_value_usd_per_hour":
                            base_obj.time_value_usd_per_hour,
                        "budget_usd": base_obj.budget_usd,
                        "deadline_s": base_obj.deadline_s,
                    })
            else:
                jrnl.append(
                    "RESUME", resumes=_prior.resumes + 1,
                    spent_usd=round(_prior.spent_usd(), 6),
                    dropped_records=_prior.dropped_records,
                    frontier=sorted(f"{a}[{p}]"
                                    for a, p in _prior.frontier()))
        try:
            return self._run(run_id, base_obj, names, tasks, records, plan,
                             force, _prior)
        finally:
            if jrnl is not None:
                jrnl.close()
            self._jrnl = None

    def _run(self, run_id: str, base_obj, names: list[str],
             tasks: dict[tuple[str, str], _Task], records: list[TaskRecord],
             plan: RunPlan | None, force: bool,
             _prior: JournalState | None) -> RunReport:

        # upfront per-(asset, partition) staleness resolution: pessimistic
        # verdicts (stale upstream poisons downstream) drive telemetry and
        # match what plan() priced; the launch-time fingerprint check below
        # still grants early cutoff when a re-run upstream reproduces
        # byte-identical data
        if self.use_cache:
            for tk, st in resolve_staleness(
                    self.graph, self.store, names, force=force).items():
                if tk in tasks and not st.fresh:
                    self.reader.emit(run_id, tk[0], tk[1], "", "STALE",
                                     reason=st.reason)

        slots: dict[str, int] = {}  # platform -> current slot budget
        running: list[_Task] = []
        done: set[tuple[str, str]] = set()
        failed_hard: set[tuple[str, str]] = set()

        def deps_ready(t: _Task) -> bool:
            for d in t.spec.deps:
                dspec = self.graph[d]
                for k in self._dep_keys(dspec, t.partition):
                    if (d, k) not in done:
                        return False
            return True

        def dep_values(t: _Task) -> dict[str, Any]:
            vals: dict[str, Any] = {}
            for d in t.spec.deps:
                dspec = self.graph[d]
                keys = self._dep_keys(dspec, t.partition)
                if len(keys) == 1:
                    vals[d] = self.store.get(d, keys[0])
                else:
                    vals[d] = {k: self.store.get(d, k) for k in keys}
            return vals

        def upstream_hashes(t: _Task) -> dict[str, str] | None:
            """Content hashes of this task's upstream materializations, or
            ``None`` when any record is missing — a missing upstream forces
            staleness outright (no "?" placeholder that could collide with a
            real hash and fake freshness)."""
            out: dict[str, str] = {}
            for d in t.spec.deps:
                dspec = self.graph[d]
                for k in self._dep_keys(dspec, t.partition):
                    h = self.store.data_hash(d, k)
                    if h is None:
                        return None
                    out[f"{d}[{k}]"] = h
            return out

        cver: dict[str, str] = {}  # asset -> code version (memoized)

        pending = list(tasks.values())
        if _prior is not None:
            self._apply_prior(run_id, _prior, tasks, done, pending)
        while pending or running:
            # ---------------- launch ready tasks ------------------------
            now = time.time()
            # fleet-wide platform eviction: platforms with an open circuit
            # breaker are denied for every task (a half-open breaker admits
            # exactly one probe launch per cooldown)
            open_plats = (self.adaptive.open_platforms(now)
                          if self.adaptive is not None else set())
            launchable = [t for t in pending
                          if deps_ready(t) and now >= t.next_eligible]
            for t in launchable:
                if len(running) >= self.max_concurrent:
                    break
                # cache hit?  checked at launch time (deps are done) so an
                # upstream that re-ran but reproduced identical data still
                # short-circuits this task — early cutoff
                up = upstream_hashes(t)
                t.code_version = cver.get(t.spec.name) or cver.setdefault(
                    t.spec.name, code_version(t.spec))
                t.upstream = up or {}
                fp = self.store.fingerprint(t.code_version, t.partition,
                                            t.upstream) if up is not None \
                    else ""
                t.fingerprint = fp
                if self.use_cache and not force and fp and \
                        self.store.is_fresh(t.spec.name, t.partition, fp):
                    t.record.status = "success"
                    t.record.cached = True
                    done.add((t.spec.name, t.partition))
                    pending.remove(t)
                    self.reader.emit(run_id, t.spec.name, t.partition,
                                     "cache", "CACHE_HIT", fingerprint=fp)
                    self.reader.emit(run_id, t.spec.name, t.partition,
                                     "cache", "SUCCESS", duration_s=0.0,
                                     cached=True)
                    if self._jrnl is not None:
                        self._jrnl.append(
                            "SUCCESS", asset=t.spec.name,
                            partition=t.partition, platform="cache",
                            cached=True, fingerprint=fp,
                            data_hash=self.store.data_hash(
                                t.spec.name, t.partition))
                    # a resumed prepaid attempt resolved by early cutoff
                    # (upstream re-ran byte-identical): the crashed run's
                    # bill still belongs in this report
                    for pk in [k for k in self._prepaid
                               if k[:2] == (t.spec.name, t.partition)]:
                        sim_p, cost_p = self._prepaid.pop(pk)
                        t.record.attempts.append(AttemptRecord(
                            t.replay.get(pk[2], ""), "success", sim_p,
                            cost_p))
                    continue
                platform = est = None
                # resume replay: the crashed run journaled a LAUNCH for the
                # attempt we are about to make — re-launch it on the same
                # platform so the deterministic client key (run, asset,
                # partition, attempt, platform) reproduces the attempt
                rp = t.replay.get(t.attempt + 1)
                if rp is not None and rp in self.factory.catalog:
                    platform = self.factory.catalog[rp]
                    est = self.factory.cost_model.estimate(t.spec, platform)
                if plan is not None and platform is None:
                    pc = plan.choice(t.spec.name, t.partition)
                    if (pc is not None and pc.platform not in t.deny
                            and pc.platform not in open_plats
                            and pc.platform in self.factory.catalog):
                        platform = self.factory.catalog[pc.platform]
                        est = pc.estimate
                if platform is None:
                    # no plan, or the planned platform was deny-listed after
                    # failures / tripped its breaker: fall back to the
                    # greedy per-task factory
                    try:
                        platform, est = self.factory.choose(
                            t.spec, deny=t.deny | open_plats)
                    except RuntimeError:
                        try:
                            # breakers made it unsolvable: a sick platform
                            # beats no platform — ignore breakers, keep the
                            # per-task deny list
                            platform, est = self.factory.choose(t.spec,
                                                                deny=t.deny)
                        except RuntimeError:
                            # every platform deny-listed: reset and take the
                            # best remaining option (failures were transient)
                            t.deny.clear()
                            self.reader.emit(run_id, t.spec.name, t.partition,
                                             "", "DENY_RESET")
                            platform, est = self.factory.choose(t.spec)
                # elastic scaling: grow this platform's slot budget while a
                # backlog exists (paper: "automatic scaling")
                cur = slots.get(platform.name, self.platform_slots)
                in_use = sum(1 for r in running
                             if r.handle and r.handle.platform == platform.name)
                if in_use >= cur:
                    if cur < self.elastic_max_slots:
                        slots[platform.name] = cur + 1
                        self.reader.emit(run_id, t.spec.name, t.partition,
                                         platform.name, "SCALING",
                                         slots=cur + 1)
                    else:
                        continue  # saturated; try next loop
                t.attempt += 1
                t.estimate = est
                ctx = self.injector.build(run_id, t.spec, t.partition,
                                          platform, t.attempt)
                job = JobSpec(fn=t.spec.fn, args=(), kwargs=dep_values(t),
                              ctx=ctx, estimate=est)
                self.reader.emit(run_id, t.spec.name, t.partition,
                                 platform.name, "SUBMIT",
                                 attempt=t.attempt,
                                 est_usd=est.total_usd,
                                 est_duration_s=est.duration_s,
                                 planned=plan is not None)
                if self._jrnl is not None:
                    # WAL ordering: the LAUNCH record is durable before the
                    # job exists — a crash between the two re-launches an
                    # attempt that never ran (harmless), never the reverse
                    # (an attempt running with no record of it)
                    self._jrnl.append(
                        "LAUNCH", asset=t.spec.name, partition=t.partition,
                        platform=platform.name, attempt=t.attempt,
                        est_usd=est.total_usd, est_duration_s=est.duration_s)
                t.handle = self.factory.client(platform).submit(job)
                t.launched_at = now
                if self.adaptive is not None:
                    self.adaptive.note_launch(platform.name, now)
                pending.remove(t)
                running.append(t)
                self.reader.emit(run_id, t.spec.name, t.partition,
                                 platform.name, "START", attempt=t.attempt)

            # ---------------- check completions -------------------------
            time.sleep(0.0005)
            for t in list(running):
                prim, spec = t.handle, t.spec_handle
                prim_done = prim is not None and prim.done()
                spec_done = spec is not None and spec.done()
                if not (prim_done or spec_done):
                    self._maybe_speculate(run_id, t)
                    continue
                prim_ok = (prim_done and prim.error is None
                           and not prim.cancelled)
                spec_ok = (spec_done and spec is not None
                           and spec.error is None and not spec.cancelled)

                if prim_ok or spec_ok:
                    if prim_ok:
                        h, est, speculative, other, o_est = (
                            prim, t.estimate, False, spec, t.spec_estimate)
                    else:
                        h, est, speculative, other, o_est = (
                            spec, t.spec_estimate, True, prim, t.estimate)
                    running.remove(t)
                    if other is not None and not other.done():
                        other.cancelled = True
                        self.reader.emit(run_id, t.spec.name, t.partition,
                                         other.platform, "CANCEL",
                                         reason="speculative twin won")
                        t.record.attempts.append(AttemptRecord(
                            other.platform, "cancelled", 0.0, 0.0))
                    elif other is not None and other.error is not None:
                        self._record_failed_attempt(run_id, t, other, o_est)
                    self._on_success(run_id, t, h, est, speculative, done)
                    t.handle = t.spec_handle = None
                    continue

                # a speculative twin failed while the primary still runs:
                # bill + record it, drop the twin, keep waiting
                if spec_done and spec is not None and not spec_ok \
                        and not prim_done:
                    self._record_failed_attempt(run_id, t, spec,
                                                t.spec_estimate)
                    t.spec_handle = t.spec_estimate = None
                    continue

                # primary failed (twin absent, failed, or also finished)
                running.remove(t)
                if spec_done and spec is not None and not spec_ok:
                    self._record_failed_attempt(run_id, t, spec,
                                                t.spec_estimate)
                self._on_failure(run_id, t, prim, t.estimate, pending,
                                 failed_hard)
                t.handle = t.spec_handle = None

            # ---------------- closed loop: learn / trip / replan ---------
            if self.adaptive is not None:
                plan = self._adaptive_step(run_id, names, base_obj, plan,
                                           tasks, pending, records, force)

        report = RunReport(run_id=run_id, records=records, graph=self.graph)
        if self._jrnl is not None:
            self._jrnl.append("END", ok=report.ok,
                              total_cost_usd=round(report.total_cost, 6),
                              tasks=len(records))
        return report

    def _adaptive_step(self, run_id: str, names: list[str], base_obj,
                       plan: RunPlan | None, tasks: dict,
                       pending: list, records: list,
                       force: bool) -> RunPlan | None:
        """One closed-loop tick: ingest fresh telemetry into the online
        model / drift detector / breakers, emit breaker transitions, and —
        when drift fires — replan the not-yet-launched tasks under the
        remaining budget/deadline.  Returns the (possibly new) plan."""
        ctl = self.adaptive
        outcomes, transitions = ctl.ingest(self.reader)
        for plat, state in transitions:
            self.reader.emit(run_id, "", "", plat, "BREAKER", state=state,
                             consecutive_failures=ctl.breakers[plat].consecutive)
        if not outcomes:
            return plan  # nothing new happened; drift verdict is unchanged
        now = time.time()
        reasons = ctl.should_replan(now)
        if not reasons or not pending:
            return plan
        # in-flight and finished tasks keep their assignments: replan only
        # what is still pending (the set of non-pending keys is
        # predecessor-closed — a task launches only after its deps finish)
        pending_keys = {(t.spec.name, t.partition) for t in pending}
        exclude = set(tasks) - pending_keys
        obj = base_obj
        spent = sum(r.total_cost for r in records)
        elapsed = RunReport(run_id, records, self.graph).makespan_s()
        remaining_budget = (None if obj.budget_usd is None
                            else obj.budget_usd - spent)
        remaining_deadline = (None if obj.deadline_s is None
                              else max(obj.deadline_s - elapsed, 0.0))
        planner = RunPlanner(
            self.graph, ctl.planning_factory(self.factory, now),
            slots=self.slots,
            store=self.store if self.use_cache else None,
            preemption_aware=True)
        try:
            new_plan = planner.plan(
                names, obj.constrained(budget_usd=remaining_budget,
                                       deadline_s=remaining_deadline),
                force=force, exclude=exclude)
        except RuntimeError:
            # e.g. every platform for some asset breaker-evicted AND
            # infeasible — keep flying on the old plan
            ctl.note_replanned(now, reasons, adopted=False)
            return plan
        adopted = new_plan.feasible
        ctl.note_replanned(now, reasons, adopted=adopted)
        self.reader.emit(
            run_id, "", "", "", "REPLAN", reasons=reasons,
            adopted=adopted, replans=ctl.replans,
            pending_tasks=len(pending_keys),
            predicted_cost_usd=new_plan.predicted_cost_usd,
            predicted_makespan_s=new_plan.predicted_makespan_s)
        if self._jrnl is not None:
            self._jrnl.append("REPLAN", adopted=adopted, reasons=reasons,
                              replans=ctl.replans,
                              pending=len(pending_keys))
        # an infeasible remainder-plan (budget already blown, deadline
        # already passed) is advice we cannot execute: keep the old plan
        return new_plan if adopted else plan

    # ------------------------------------------------------------ internals
    def _dep_keys(self, dspec: AssetSpec, partition: str) -> list[str]:
        # memoized: called from every deps_ready poll in the launch loop
        ck = (dspec.name, partition)
        out = self._dep_key_cache.get(ck)
        if out is None:
            out = dep_partition_keys(dspec.partitions, partition)
            self._dep_key_cache[ck] = out
        return out

    def _maybe_speculate(self, run_id: str, t: _Task) -> None:
        if (not self.enable_speculation or t.spec_handle is not None
                or t.speculated or t.handle is None):
            return
        med = self.reader.median_duration(t.spec.name)
        if med is None:
            return
        elapsed = time.time() - t.launched_at
        sim_scale = getattr(self.factory.client(
            self.factory.catalog[t.handle.platform]), "sim_time_scale", 0.0)
        if sim_scale <= 0.0:
            # pure-accounting mode: runs complete instantly, so wall-clock
            # carries no straggler signal — speculating here would just add
            # load-dependent nondeterminism (real clients always have one)
            return
        threshold = max(self.straggler_min_s,
                        self.straggler_factor * med * sim_scale)
        if elapsed < threshold:
            return
        try:
            platform, est = self.factory.choose(t.spec,
                                                deny={t.handle.platform})
        except RuntimeError:
            return
        ctx = self.injector.build(run_id, t.spec, t.partition, platform,
                                  t.attempt, overrides={"SPECULATIVE": "1"})
        # speculative duplicate re-reads inputs from the store
        vals = {}
        for d in t.spec.deps:
            dspec = self.graph[d]
            keys = self._dep_keys(dspec, t.partition)
            vals[d] = (self.store.get(d, keys[0]) if len(keys) == 1
                       else {k: self.store.get(d, k) for k in keys})
        job = JobSpec(fn=t.spec.fn, args=(), kwargs=vals, ctx=ctx,
                      estimate=est)
        if self._jrnl is not None:
            self._jrnl.append(
                "LAUNCH", asset=t.spec.name, partition=t.partition,
                platform=platform.name, attempt=t.attempt, speculative=True)
        t.spec_handle = self.factory.client(platform).submit(job)
        t.spec_estimate = est
        self.reader.emit(run_id, t.spec.name, t.partition, platform.name,
                         "SPECULATE", original=t.handle.platform)
        t.speculated = True

    def _bill(self, run_id: str, t: _Task, h: RunHandle,
              est: CostEstimate | None, outcome: str = "success",
              speculative: bool = False) -> tuple[float, float]:
        # exactly-once billing across crashes: an attempt the crashed run
        # already billed (success journaled, output never landed) carries
        # its journaled money forward instead of paying twice
        prepaid = (self._prepaid.pop((t.spec.name, t.partition, t.attempt),
                                     None) if not speculative else None)
        if prepaid is not None:
            sim, cost = prepaid
        else:
            est_total = est.total_usd if est else 0.0
            est_dur = est.duration_s if est else 1e-9
            sim = h.sim_duration_s or max(h.finished - h.started, 1e-9)
            cost = est_total * (sim / max(est_dur, 1e-9))
        if self._jrnl is not None and prepaid is None:
            # money truth: the BILL record is durable before the store put /
            # telemetry — resume trusts the journal, never re-derives spend
            self._jrnl.append(
                "BILL", asset=t.spec.name, partition=t.partition,
                platform=h.platform, attempt=t.attempt, cost_usd=cost,
                sim_duration_s=sim, outcome=outcome, speculative=speculative,
                est_duration_s=(est.duration_s if est else 0.0))
        # outcome + predicted duration ride along so the adaptive
        # controller can learn realized/predicted ratios and success rates
        # from the COST stream alone
        self.reader.emit(run_id, t.spec.name, t.partition, h.platform,
                         "COST", total_usd=cost, duration_s=sim,
                         attempt=t.attempt, outcome=outcome,
                         prepaid=prepaid is not None,
                         est_duration_s=(est.duration_s if est else 0.0))
        return sim, cost

    def _record_failed_attempt(self, run_id: str, t: _Task, h: RunHandle,
                               est: CostEstimate | None) -> None:
        """A failed handle that does NOT end the task (e.g. a speculative
        twin): billed and recorded, no retry bookkeeping."""
        kind = (h.error.kind if isinstance(h.error, PlatformError)
                else "failure")
        sim, cost = self._bill(run_id, t, h, est, outcome=kind,
                               speculative=True)
        t.record.attempts.append(AttemptRecord(
            h.platform, kind, sim, cost, speculative=True,
            error=str(h.error)))
        self.reader.emit(run_id, t.spec.name, t.partition, h.platform,
                         "FAILURE", attempt=t.attempt, failure_kind=kind,
                         speculative=True, error=str(h.error))

    def _on_success(self, run_id: str, t: _Task, h: RunHandle,
                    est: CostEstimate | None, speculative: bool,
                    done: set) -> None:
        # write ordering contract: BILL (journal) -> put (store) -> SUCCESS
        # (journal).  A crash after BILL but before put leaves a success-
        # billed attempt with no data: resume re-runs it prepaid.  A crash
        # after put but before SUCCESS leaves landed data: resume trusts
        # the store (data truth) and marks the task done.
        sim, cost = self._bill(run_id, t, h, est, outcome="success",
                               speculative=speculative)
        self.store.put(t.spec.name, t.partition, h.result, t.fingerprint,
                       meta={"platform": h.platform, "run_id": run_id},
                       code_version=t.code_version, upstream=t.upstream)
        if self._jrnl is not None:
            self._jrnl.append(
                "SUCCESS", asset=t.spec.name, partition=t.partition,
                platform=h.platform, attempt=t.attempt,
                fingerprint=t.fingerprint, speculative=speculative,
                data_hash=self.store.data_hash(t.spec.name, t.partition))
        t.record.attempts.append(AttemptRecord(
            h.platform, "success", sim, cost, speculative))
        t.record.status = "success"
        done.add((t.spec.name, t.partition))
        self.reader.emit(run_id, t.spec.name, t.partition, h.platform,
                         "MATERIALIZE", fingerprint=t.fingerprint)
        self.reader.emit(run_id, t.spec.name, t.partition, h.platform,
                         "SUCCESS", duration_s=sim, cost_usd=cost,
                         speculative=speculative)

    def _on_failure(self, run_id: str, t: _Task, h: RunHandle,
                    est: CostEstimate | None, pending: list,
                    failed_hard: set) -> None:
        kind = (h.error.kind if isinstance(h.error, PlatformError)
                else "failure")
        sim, cost = self._bill(run_id, t, h, est, outcome=kind)
        t.record.attempts.append(AttemptRecord(
            h.platform, kind, sim, cost, error=str(h.error)))
        self.reader.emit(run_id, t.spec.name, t.partition, h.platform,
                         "FAILURE", attempt=t.attempt, failure_kind=kind,
                         error=str(h.error))
        if t.attempt >= t.spec.retry.max_attempts:
            t.record.status = "failed"
            failed_hard.add((t.spec.name, t.partition))
            if self._jrnl is not None:
                # durable tombstone: resume refuses to retry past an
                # exhausted budget instead of silently re-running the task
                self._jrnl.append(
                    "FAIL", asset=t.spec.name, partition=t.partition,
                    platform=h.platform, attempt=t.attempt,
                    error=str(h.error))
                self._jrnl.append("END", ok=False)
            raise RuntimeError(
                f"asset {t.spec.name}[{t.partition}] failed after "
                f"{t.attempt} attempts: {h.error}")
        if t.attempt >= t.spec.retry.failover_after:
            t.deny.add(h.platform)
            self.reader.emit(run_id, t.spec.name, t.partition, h.platform,
                             "FAILOVER", deny=sorted(t.deny))
        self.reader.emit(run_id, t.spec.name, t.partition, h.platform,
                         "RETRY", attempt=t.attempt + 1)
        # capped exponential backoff with deterministic per-task jitter
        # (see RetryPolicy.delay_s) — retries decorrelate without RNG state
        t.next_eligible = time.time() + t.spec.retry.delay_s(
            t.attempt, (t.spec.name, t.partition))
        t.speculated = False  # the retry may speculate once again
        pending.append(t)

    # ------------------------------------------------------------ resume
    @staticmethod
    def _attempt_from_bill(b: dict) -> AttemptRecord:
        p = b["payload"]
        return AttemptRecord(
            b["platform"], p.get("outcome", "success"),
            p.get("sim_duration_s", 0.0), p.get("cost_usd", 0.0),
            speculative=bool(p.get("speculative")))

    def _apply_prior(self, run_id: str, prior: JournalState,
                     tasks: dict[tuple[str, str], _Task], done: set,
                     pending: list) -> None:
        """Reconcile the replayed journal against the store and prefill the
        fresh task table so only the crash frontier re-executes.

        Per task: *done* iff its output landed (store record written by this
        run, or a journaled SUCCESS whose record still exists — the store is
        data truth, the journal is money truth); journaled FAIL re-raises
        (the retry budget was exhausted durably); everything else replays —
        terminal bills prefill attempts/deny/backoff state, a success bill
        whose put never landed becomes *prepaid* (re-executed, not re-billed)
        and in-flight launches pin their attempt to the journaled platform
        so deterministic clients reproduce the interrupted attempt."""
        for tk, t in tasks.items():
            asset, part = tk
            bills = prior.bills_by_task.get(tk, [])
            if tk in prior.failed:
                if self._jrnl is not None:
                    self._jrnl.append("END", ok=False)
                raise RuntimeError(
                    f"asset {asset}[{part}] hard-failed in run "
                    f"{prior.run_id} (journaled FAIL after "
                    f"{max((b['attempt'] for b in bills), default=0)} "
                    f"attempts); resume will not retry past an exhausted "
                    f"attempt budget")
            rec = self.store.record(asset, part)
            landed = rec is not None and (
                tk in prior.succeeded
                or rec.get("meta", {}).get("run_id") == run_id)
            if landed:
                # a landed output only counts if every upstream it was built
                # from is itself carried-done with an unchanged data hash
                # (tasks iterate in topo order, so deps resolved first) — a
                # quarantined/re-running upstream demotes this task to the
                # frontier rather than letting it serve stale data
                for d in t.spec.deps:
                    for k in self._dep_keys(self.graph[d], part):
                        h = self.store.data_hash(d, k)
                        if (d, k) not in done or h is None or \
                                rec.get("upstream", {}).get(
                                    f"{d}[{k}]") != h:
                            landed = False
                            break
                    if not landed:
                        break
            if landed:
                # durably done: carry the journaled money into the report
                for b in bills:
                    t.record.attempts.append(self._attempt_from_bill(b))
                t.record.status = "success"
                t.record.cached = bool(
                    prior.succeeded.get(tk, {}).get("payload", {})
                    .get("cached"))
                t.attempt = max((b["attempt"] for b in bills), default=0)
                done.add(tk)
                pending.remove(t)
                self.reader.emit(run_id, asset, part, rec.get(
                    "meta", {}).get("platform", ""), "CARRIED",
                    attempts=len(bills))
                continue
            # replays: prefill terminal attempts the crashed run paid for
            success_bill = None
            for b in bills:
                if b["payload"].get("outcome") == "success" \
                        and success_bill is None:
                    success_bill = b  # goes prepaid, not into the report
                    continue
                t.record.attempts.append(self._attempt_from_bill(b))
                if not b["payload"].get("speculative") \
                        and b["attempt"] >= t.spec.retry.failover_after:
                    t.deny.add(b["platform"])
            failed_attempts = prior.terminal_attempts(tk)
            t.attempt = max(failed_attempts, default=0)
            if len(failed_attempts) >= t.spec.retry.max_attempts \
                    and success_bill is None:
                raise RuntimeError(
                    f"asset {asset}[{part}] exhausted its "
                    f"{t.spec.retry.max_attempts}-attempt budget in run "
                    f"{prior.run_id}; refusing to resume past it")
            if success_bill is not None:
                # crash fell between BILL and store.put: re-execute the
                # attempt, but carry the journaled money (exactly-once)
                p = success_bill["payload"]
                self._prepaid[(asset, part, success_bill["attempt"])] = (
                    p.get("sim_duration_s", 0.0), p.get("cost_usd", 0.0))
                t.replay[success_bill["attempt"]] = success_bill["platform"]
                t.attempt = success_bill["attempt"] - 1
            else:
                orphans = prior.in_flight().get(tk, [])
                if orphans:
                    # the launch the crash cut down: same attempt number +
                    # platform -> the deterministic client replays it
                    a = max(r["attempt"] for r in orphans)
                    for r in orphans:
                        t.replay[r["attempt"]] = r["platform"]
                    t.attempt = a - 1

    def _prior_makespan(self, prior: JournalState) -> float:
        """Simulated elapsed time the crashed run already consumed,
        reconstructed from its BILL records (feeds remaining-deadline)."""
        recs = []
        for tk, bills in prior.bills_by_task.items():
            r = TaskRecord(asset=tk[0], partition=tk[1])
            r.attempts = [self._attempt_from_bill(b) for b in bills]
            r.status = "success" if tk in prior.succeeded else "pending"
            recs.append(r)
        return RunReport(prior.run_id, recs, self.graph).makespan_s()

    def resume(self, run_id: str, replan: bool = True) -> RunReport:
        """Reopen a crashed run from its write-ahead journal.

        Replays the journal (torn-tail tolerant), sweeps the target cone's
        store records for integrity (corrupt blobs quarantine and re-run),
        warm-starts the adaptive controller from journaled bills, replans
        the remainder under the *remaining* budget/deadline, then executes
        only the crash frontier — done work is carried, billed attempts are
        never billed twice."""
        if self.journal_dir is None:
            raise ValueError("resume() requires a coordinator constructed "
                             "with journal_dir")
        recs, dropped = RunJournal.load(self.journal_dir, run_id)
        prior = JournalState.from_records(recs, dropped)
        if prior.ended and prior.ok:
            raise ValueError(f"run {run_id} already ended ok; "
                             f"nothing to resume")
        names = AssetSelection.coerce(prior.targets).resolve(self.graph)
        for name in self.graph.topo_order(names):
            for key in partition_keys(self.graph[name].partitions):
                self.store.verify(name, key)
        if self.adaptive is not None and prior.bills:
            self.adaptive.warm_start(prior.bills)
        plan = None
        if replan and prior.planned:
            obj = Objective(
                name=prior.objective.get("name",
                                         self.factory.objective.name),
                time_value_usd_per_hour=prior.objective.get(
                    "time_value_usd_per_hour",
                    self.factory.objective.time_value_usd_per_hour),
                budget_usd=prior.objective.get("budget_usd"),
                deadline_s=prior.objective.get("deadline_s"))
            remaining_budget = (
                None if obj.budget_usd is None
                else max(obj.budget_usd - prior.spent_usd(), 0.0))
            remaining_deadline = (
                None if obj.deadline_s is None
                else max(obj.deadline_s - self._prior_makespan(prior), 0.0))
            try:
                plan = self.plan(names, obj.constrained(
                    budget_usd=remaining_budget,
                    deadline_s=remaining_deadline))
            except RuntimeError:
                plan = None
            if plan is not None and not plan.feasible:
                # an unplannable remainder (budget already blown) must not
                # strand the run: fall back to greedy best-effort recovery
                plan = None
        return self.materialize(names, run_id=run_id, plan=plan,
                                force=prior.force, _prior=prior)
