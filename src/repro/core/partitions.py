"""Partitioning: time-window x static-domain, matching the paper's scheme
("data is partitioned along two primary dimensions: time and domain").

Keys are strings; multi-partition keys join dimensions with '/'.
"""
from __future__ import annotations

import dataclasses
import itertools


class PartitionsDefinition:
    def keys(self) -> list[str]:
        raise NotImplementedError

    def __contains__(self, key: str) -> bool:
        return key in self.keys()

    def __len__(self) -> int:
        return len(self.keys())


@dataclasses.dataclass(frozen=True)
class StaticPartitions(PartitionsDefinition):
    names: tuple[str, ...]

    def keys(self) -> list[str]:
        return list(self.names)


@dataclasses.dataclass(frozen=True)
class TimeWindowPartitions(PartitionsDefinition):
    """Monthly windows like Common Crawl CC-MAIN snapshots."""

    start: str  # "2023-10"
    end: str  # "2024-03" inclusive

    def keys(self) -> list[str]:
        # memoized via __dict__ (bypasses the frozen-dataclass setattr
        # guard): key expansion is hot in staleness resolution and task-DAG
        # builds, and the fields are immutable
        cached = self.__dict__.get("_keys")
        if cached is None:
            y0, m0 = map(int, self.start.split("-"))
            y1, m1 = map(int, self.end.split("-"))
            out = []
            y, m = y0, m0
            while (y, m) <= (y1, m1):
                out.append(f"{y:04d}-{m:02d}")
                m += 1
                if m > 12:
                    y, m = y + 1, 1
            cached = self.__dict__["_keys"] = out
        return list(cached)

    @staticmethod
    def of(*keys: str) -> "StaticPartitions":
        return StaticPartitions(tuple(keys))


@dataclasses.dataclass(frozen=True)
class MultiPartitions(PartitionsDefinition):
    """Cross-product, e.g. crawl-month x domain-shard."""

    dims: tuple[tuple[str, PartitionsDefinition], ...]

    def keys(self) -> list[str]:
        cached = self.__dict__.get("_keys")
        if cached is None:
            parts = [d.keys() for _, d in self.dims]
            cached = self.__dict__["_keys"] = [
                "/".join(combo) for combo in itertools.product(*parts)]
        return list(cached)

    def split(self, key: str) -> dict[str, str]:
        vals = key.split("/")
        assert len(vals) == len(self.dims), (key, self.dims)
        return {name: v for (name, _), v in zip(self.dims, vals)}


def partition_keys(p: PartitionsDefinition | None) -> list[str]:
    """None => a single unpartitioned pseudo-key."""
    return p.keys() if p is not None else ["__all__"]


def dep_partition_keys(dep: PartitionsDefinition | None,
                       partition: str,
                       dkeys: list[str] | None = None) -> list[str]:
    """Which upstream partitions a task with ``partition`` consumes: the
    matching key when partitionings align, every key on fan-in.

    ``dkeys`` lets hot callers (``schedule.task_dag`` expands 10k-task DAGs)
    pass the upstream's already-expanded ``partition_keys`` so it is not
    recomputed per task; semantics are identical."""
    if dkeys is None:
        dkeys = partition_keys(dep)
    if partition in dkeys:
        return [partition]
    if dkeys == ["__all__"]:
        return ["__all__"]
    return dkeys  # fan-in: downstream consumes every upstream partition
