"""Seeded, deterministic fault injection for chaos-testing the run path.

``FaultPlan`` is the single knob a chaos test turns: it is threaded through
the ``RunCoordinator`` (journal-record-boundary kill points — the worst
case a real crash can produce, since the record is durable but the action
it describes may not have happened) and the ``DynamicClientFactory``
(client-level failure/slowdown overrides on named platforms), and offers
seeded on-disk corruption helpers (blob bit-flips/truncation, torn index
writes) for the ``MaterializationStore`` hardening tests.

Everything is deterministic in ``seed`` plus the target identity, so a
failing chaos run replays exactly — the same property the simulated
clients already have for task-level faults (Fig-3 reproducibility), lifted
to orchestrator-level faults.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os

import numpy as np


class CoordinatorKilled(RuntimeError):
    """The fault plan killed the coordinator at a journal record boundary.

    In-process stand-in for SIGKILL/power loss: the coordinator loop stops
    dead (no more store writes, no more journal records), while worker
    threads it had launched are orphaned — just like a real crash leaves
    remote jobs running with nobody to collect them."""

    def __init__(self, record_seq: int):
        super().__init__(f"fault plan killed coordinator after journal "
                         f"record {record_seq}")
        self.record_seq = record_seq


@dataclasses.dataclass(frozen=True)
class ClientFaults:
    """Platform-client fault overrides (reality diverging from catalog)."""

    platforms: tuple[str, ...] = ()  # empty = every platform
    failure_rate: float | None = None
    preemption_rate: float | None = None
    slowdown: float = 1.0  # duration bias multiplier (>1 = slower)

    def applies_to(self, platform: str) -> bool:
        return not self.platforms or platform in self.platforms


@dataclasses.dataclass
class FaultPlan:
    """One reproducible chaos scenario.

    ``kill_at_record`` — raise ``CoordinatorKilled`` immediately after the
    Nth journal record (1-based) becomes durable; ``None`` disables.
    ``client`` — fault overrides applied by ``DynamicClientFactory.client``
    when building simulated platform clients.
    The ``corrupt_blob`` / ``truncate_blob`` / ``tear_index`` helpers mangle
    a store directory the way partial hardware failures do, with the byte
    positions drawn from ``seed`` so every run mangles identically.
    """

    seed: int = 0
    kill_at_record: int | None = None
    client: ClientFaults | None = None

    # ------------------------------------------------------------ kill point
    def journal_barrier(self, n_records: int) -> None:
        """Called by ``RunJournal.append`` after each durable record."""
        if self.kill_at_record is not None \
                and n_records >= self.kill_at_record:
            raise CoordinatorKilled(n_records)

    # --------------------------------------------------------------- clients
    def client_faults(self, platform: str) -> ClientFaults | None:
        if self.client is not None and self.client.applies_to(platform):
            return self.client
        return None

    # ------------------------------------------------------------------ disk
    def _rng(self, *key: object) -> np.random.RandomState:
        digest = hashlib.sha1(repr((self.seed,) + key).encode()).digest()
        return np.random.RandomState(
            int.from_bytes(digest[:4], "little") % (2 ** 31))

    def corrupt_blob(self, store_dir: str, data_hash: str) -> int:
        """Flip one seeded byte in a blob; returns the flipped offset."""
        path = os.path.join(store_dir, "blobs", f"{data_hash}.pkl")
        with open(path, "rb") as f:
            blob = bytearray(f.read())
        if not blob:
            raise ValueError(f"blob {data_hash} is empty")
        off = int(self._rng("corrupt", data_hash).randint(len(blob)))
        blob[off] ^= 0xFF
        with open(path, "wb") as f:
            f.write(bytes(blob))
        return off

    def truncate_blob(self, store_dir: str, data_hash: str) -> int:
        """Cut a blob to a seeded fraction of its length (torn blob write
        that dodged the tmp+rename protocol, or sector loss)."""
        path = os.path.join(store_dir, "blobs", f"{data_hash}.pkl")
        size = os.path.getsize(path)
        keep = int(self._rng("truncate", data_hash).randint(max(size, 1)))
        with open(path, "rb+") as f:
            f.truncate(keep)
        return keep

    def tear_index(self, store_dir: str) -> int:
        """Truncate ``index.json`` at a seeded offset strictly inside the
        payload — the classic torn write a non-fsync'd rename can leave
        after power loss.  Returns the kept byte count."""
        path = os.path.join(store_dir, "index.json")
        size = os.path.getsize(path)
        if size < 2:
            raise ValueError("index too small to tear")
        keep = 1 + int(self._rng("tear-index").randint(size - 1))
        with open(path, "rb+") as f:
            f.truncate(keep)
        return keep

    def tear_journal(self, journal_dir: str, run_id: str,
                     drop_bytes: int | None = None) -> int:
        """Chop seeded bytes off a journal's tail (torn final write)."""
        path = os.path.join(journal_dir, f"run-{run_id}.jsonl")
        size = os.path.getsize(path)
        drop = (drop_bytes if drop_bytes is not None
                else 1 + int(self._rng("tear-journal", run_id)
                             .randint(min(40, max(size - 1, 1)))))
        with open(path, "rb+") as f:
            f.truncate(max(size - drop, 0))
        return drop

    def describe(self) -> str:
        return json.dumps(dataclasses.asdict(self), default=str,
                          sort_keys=True)
