"""Incremental, slot-aware scheduling engine — the planner's inner loop.

``RunPlanner`` (PR 2) re-ran a full critical-path pass over all *n* tasks for
every upgrade/downgrade candidate trial, and its schedule assumed infinite
width per platform while the ``RunCoordinator`` executes with finite elastic
slots.  This module fixes both:

* ``ScheduleEngine`` keeps one mutable schedule state and offers **O(cone)
  incremental retiming** (``set_duration`` / ``try_duration``): a duration
  change only re-times the affected descendant cone, and slack is re-derived
  lazily (one backward pass per batch, not per trial).
* ``slot_schedule`` is a **finite-capacity list scheduler**: per-platform
  slot budgets plus the global concurrency cap, exactly the knobs the
  coordinator runs with (shared via ``SlotConfig``), so predicted makespans
  stay honest under contention.
* ``task_dag`` expands the (asset, partition) task DAG once, caching
  ``partition_keys()`` / ``dep_partition_keys()`` per asset instead of
  re-expanding them per task — hot at 10k tasks.

Both the planner (predictions) and the coordinator (``RunReport.
slot_makespan_s`` replay) consume this engine, so plan and execution agree
on what a slot is.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.assets import AssetGraph

TaskKey = tuple[str, str]  # (asset, partition)

#: slack below this fraction of the makespan counts as "on the critical path"
CRITICAL_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class SlotConfig:
    """Concurrency limits shared by the planner and the coordinator.

    ``platform_slots`` is the initial per-platform budget; the coordinator's
    elastic scaler grows a backlogged platform one slot per blocked launch
    attempt up to ``elastic_max_slots``.  The ramp takes milliseconds against
    hour-scale tasks, so the *steady-state* width — ``elastic_max_slots``,
    still capped by ``max_concurrent`` globally — is what a schedule sees.
    """

    max_concurrent: int = 8
    platform_slots: int = 2
    elastic_max_slots: int = 8

    def capacity(self, platform: str) -> int:
        """Steady-state concurrent-task width for one platform.  The
        coordinator's budget starts at ``platform_slots`` and only ever
        grows (toward ``elastic_max_slots``), so the steady-state width is
        the larger of the two.  ``platform`` is unused today; it keeps the
        call sites ready for per-platform budget overrides."""
        return max(1, self.platform_slots, self.elastic_max_slots)


@dataclasses.dataclass
class SlotSchedule:
    """Result of one finite-capacity list-scheduling pass."""

    makespan_s: float
    start: np.ndarray  # per task index
    finish: np.ndarray
    peak_in_use: dict[str, int]  # platform -> max concurrent tasks observed
    wait_s_total: float  # total ready-but-queued time (contention signal)


def task_dag(graph: AssetGraph, targets: list[str] | None) -> tuple[
        list[TaskKey], dict[TaskKey, list[TaskKey]]]:
    """Topologically ordered (asset, partition) keys + predecessor edges.

    Partition expansion is cached per asset: ``partition_keys`` once per
    asset and ``dep_partition_keys`` once per (dep, partition) pair, instead
    of per task — the difference between O(n) and O(n * |partitions|) graph
    builds on partitioned DAGs.
    """
    order = graph.topo_order(targets)
    from repro.core.partitions import dep_partition_keys, partition_keys

    pkeys: dict[str, list[str]] = {}
    for name in order:
        pkeys[name] = partition_keys(graph[name].partitions)

    keys: list[TaskKey] = []
    preds: dict[TaskKey, list[TaskKey]] = {}
    dep_cache: dict[tuple[str, str], list[str]] = {}
    for name in order:
        spec = graph[name]
        for key in pkeys[name]:
            tk = (name, key)
            keys.append(tk)
            plist: list[TaskKey] = []
            for d in spec.deps:
                dks = dep_cache.get((d, key))
                if dks is None:
                    # canonical mapping semantics, cached expansion
                    dks = dep_partition_keys(graph[d].partitions, key,
                                             dkeys=pkeys[d])
                    dep_cache[(d, key)] = dks
                plist.extend((d, dk) for dk in dks)
            preds[tk] = plist
    return keys, preds


class ScheduleEngine:
    """One mutable schedule over a fixed task DAG.

    Keys must be topologically ordered (as ``task_dag`` returns them), so
    integer index order is a valid topological order — the incremental
    retimer and both schedulers rely on that.
    """

    def __init__(self, keys: list[TaskKey],
                 preds: dict[TaskKey, list[TaskKey]],
                 slots: SlotConfig | None = None):
        self.keys = list(keys)
        self.n = len(self.keys)
        self.index = {k: i for i, k in enumerate(self.keys)}
        self.preds: list[list[int]] = [
            [self.index[p] for p in preds[k]] for k in self.keys]
        self.succs: list[list[int]] = [[] for _ in range(self.n)]
        for i, ps in enumerate(self.preds):
            for p in ps:
                if p >= i:
                    raise ValueError(
                        f"keys not topologically ordered: {self.keys[p]} "
                        f"precedes {self.keys[i]}")
                self.succs[p].append(i)
        self.sinks = [i for i in range(self.n) if not self.succs[i]]
        self.slots = slots
        self._dur: list[float] = [0.0] * self.n
        self._platform: list[str] = [""] * self.n
        self._finish: list[float] = [0.0] * self.n
        self._start: list[float] = [0.0] * self.n
        self._slack: np.ndarray | None = None
        self._makespan = 0.0

    # ------------------------------------------------------------- loading
    def load(self, durations, platforms=None) -> float:
        """Set all durations (+ optional platforms) and run one full
        forward pass.  Returns the infinite-width (PERT) makespan."""
        self._dur = [float(d) for d in durations]
        if len(self._dur) != self.n:
            raise ValueError(f"expected {self.n} durations")
        if platforms is not None:
            self._platform = [str(p) for p in platforms]
        self._forward_full()
        return self._makespan

    def _forward_full(self) -> None:
        finish = self._finish
        starts = self._start
        dur = self._dur
        for i in range(self.n):
            start = 0.0
            for p in self.preds[i]:
                if finish[p] > start:
                    start = finish[p]
            starts[i] = start
            finish[i] = start + dur[i]
        self._makespan = max((finish[s] for s in self.sinks), default=0.0)
        self._slack = None

    # -------------------------------------------------- incremental retime
    @property
    def makespan_s(self) -> float:
        return self._makespan

    def durations(self) -> np.ndarray:
        return np.asarray(self._dur, dtype=np.float64)

    def platforms(self) -> list[str]:
        return list(self._platform)

    def set_duration(self, i: int, dur: float,
                     platform: str | None = None) -> float:
        """Change one task's duration and incrementally re-time its
        descendant cone (O(cone), not O(n)).  Returns the new makespan."""
        _, undo = self.try_duration(i, dur, platform)
        del undo  # committed
        self._slack = None
        return self._makespan

    def try_duration(self, i: int, dur: float,
                     platform: str | None = None):
        """Trial variant of ``set_duration``: returns ``(makespan, undo)``
        where calling ``undo()`` restores the previous state (including the
        cached slack, so an undone trial costs no backward pass).

        Propagation is edge-incremental: each affected node keeps its start
        (= max of predecessor finishes) cached, and a predecessor's finish
        change updates it in O(1) — a full rescan of a node's predecessor
        list happens only when the unique max *decreased*.  That makes the
        common downgrade trial (durations grow) O(cone edges touched), not
        O(in-degree) per touched node — on a fan-out DAG the sink has n
        predecessors and the old rescan made every trial O(n)."""
        old_dur = self._dur[i]
        old_plat = self._platform[i]
        old_ms = self._makespan
        old_slack = self._slack
        self._dur[i] = float(dur)
        if platform is not None:
            self._platform[i] = platform
        finish, starts, d = self._finish, self._start, self._dur
        preds, succs = self.preds, self.succs
        changed: list[tuple[int, float]] = []  # (node, old finish)
        old_starts: dict[int, float] = {}
        # indices pop in increasing order, which is topological — every
        # changed predecessor of a node applies its edge update before the
        # node itself pops, so each node pops (and re-times) at most once
        heap = [i]
        inheap = {i}
        while heap:
            j = heapq.heappop(heap)
            inheap.discard(j)
            nf = starts[j] + d[j]
            fo = finish[j]
            if nf == fo:
                continue
            changed.append((j, fo))
            finish[j] = nf
            for s in succs[j]:
                st = starts[s]
                if nf > st:  # new max
                    new_st = nf
                elif nf < st and fo >= st:  # the max itself decreased
                    new_st = 0.0
                    for p in preds[s]:
                        if finish[p] > new_st:
                            new_st = finish[p]
                else:  # below the max before and after: no effect
                    continue
                if new_st != st:
                    if s not in old_starts:
                        old_starts[s] = st
                    starts[s] = new_st
                    if s not in inheap:
                        inheap.add(s)
                        heapq.heappush(heap, s)
        if changed:
            self._makespan = max(
                (finish[s] for s in self.sinks), default=0.0)
            self._slack = None

        def undo():
            self._dur[i] = old_dur
            self._platform[i] = old_plat
            for j, f in reversed(changed):
                finish[j] = f
            for j, st in old_starts.items():
                starts[j] = st
            self._makespan = old_ms
            self._slack = old_slack

        return self._makespan, undo

    # ------------------------------------------------------ slack (lazy)
    def slack(self) -> np.ndarray:
        """Total float per task against the current PERT makespan; computed
        lazily — one backward pass per batch of committed moves."""
        if self._slack is None:
            latest = [0.0] * self.n
            finish, dur, succs = self._finish, self._dur, self.succs
            ms = self._makespan
            for i in range(self.n - 1, -1, -1):
                lt = ms
                for s in succs[i]:
                    cand = latest[s] - dur[s]
                    if cand < lt:
                        lt = cand
                latest[i] = lt
            self._slack = np.asarray(
                [latest[i] - finish[i] for i in range(self.n)],
                dtype=np.float64)
        return self._slack

    def critical_mask(self) -> np.ndarray:
        return self.slack() <= CRITICAL_EPS * max(self._makespan, 1.0)

    # ----------------------------------------------- finite-capacity pass
    def slot_schedule(self, slots: SlotConfig | None = None) -> SlotSchedule:
        """Event-driven list schedule under per-platform slot budgets and the
        global concurrency cap.  Ready tasks launch in topological-index
        order (the coordinator's FIFO launch order) whenever their platform
        has a free slot.  O(n log n)."""
        cfg = slots if slots is not None else self.slots
        n = self.n
        if n == 0:
            return SlotSchedule(0.0, np.zeros(0), np.zeros(0), {}, 0.0)
        if cfg is None:  # infinite width: the PERT forward pass
            finish = np.asarray(self._finish, dtype=np.float64)
            start = np.asarray(self._start, dtype=np.float64)
            return SlotSchedule(self._makespan, start, finish, {}, 0.0)

        fast = self._pert_feasible_schedule(cfg)
        if fast is not None:
            return fast
        if all(cfg.capacity(p) >= cfg.max_concurrent
               for p in set(self._platform)):
            return self._slot_schedule_pool(cfg)

        indeg = [len(p) for p in self.preds]
        plats = sorted(set(self._platform))
        queues: dict[str, list[int]] = {p: [] for p in plats}
        in_use = {p: 0 for p in plats}
        peak = {p: 0 for p in plats}
        cap = {p: cfg.capacity(p) for p in plats}
        ready_at = [0.0] * n
        start = np.zeros(n)
        finish = np.zeros(n)
        running: list[tuple[float, int]] = []
        global_in_use = 0
        t = 0.0
        wait = 0.0
        for i in range(n):
            if indeg[i] == 0:
                heapq.heappush(queues[self._platform[i]], i)
        n_done = 0
        while n_done < n:
            while global_in_use < cfg.max_concurrent:
                best: str | None = None
                for p in plats:
                    if queues[p] and in_use[p] < cap[p] and (
                            best is None or queues[p][0] < queues[best][0]):
                        best = p
                if best is None:
                    break
                i = heapq.heappop(queues[best])
                start[i] = t
                finish[i] = t + self._dur[i]
                wait += t - ready_at[i]
                in_use[best] += 1
                peak[best] = max(peak[best], in_use[best])
                global_in_use += 1
                heapq.heappush(running, (finish[i], i))
            if not running:
                raise RuntimeError("slot schedule stalled (cycle?)")
            t, i = heapq.heappop(running)
            while True:
                p = self._platform[i]
                in_use[p] -= 1
                global_in_use -= 1
                n_done += 1
                for s in self.succs[i]:
                    indeg[s] -= 1
                    if indeg[s] == 0:
                        ready_at[s] = t
                        heapq.heappush(queues[self._platform[s]], s)
                if running and running[0][0] <= t:
                    _, i = heapq.heappop(running)
                else:
                    break
        return SlotSchedule(float(finish.max()), start, finish, peak, wait)

    @staticmethod
    def _peak_concurrency(start: np.ndarray, finish: np.ndarray) -> int:
        """Max simultaneous tasks of an interval set, counting a task that
        finishes at t as freeing its slot before one starting at t takes it
        (the list scheduler's event order)."""
        m = len(start)
        if m == 0:
            return 0
        times = np.concatenate([start, finish])
        deltas = np.concatenate([np.ones(m, dtype=np.int64),
                                 -np.ones(m, dtype=np.int64)])
        order = np.lexsort((deltas, times))  # -1 sorts before +1 at ties
        return int(np.cumsum(deltas[order]).max())

    def _pert_feasible_schedule(self, cfg: SlotConfig) -> SlotSchedule | None:
        """Contention-free fast path: if the infinite-width (PERT) schedule
        already respects the global cap and every platform cap, the FIFO
        list schedule equals it exactly — each task launches the instant it
        becomes ready, so no event loop is needed.  A wide fan-in/out stage
        that *does* exceed a cap returns ``None`` and takes the event-driven
        pass.  Vectorised event sweep, O(n log n) in numpy.

        Requires strictly positive durations: a zero-duration task is a
        point, not an interval, and the event loop serialises the launch of
        such chains through slot turnover at a single timestamp — the
        interval profile can look feasible while FIFO order still delays a
        successor past its PERT start.  Real cost-model durations are always
        positive; the degenerate case just takes the exact event loop."""
        dur = self._dur
        if any(d <= 0.0 for d in dur):
            return None
        start = np.asarray(self._start, dtype=np.float64)
        finish = np.asarray(self._finish, dtype=np.float64)
        if self._peak_concurrency(start, finish) > cfg.max_concurrent:
            return None
        parr = np.asarray(self._platform)
        peaks: dict[str, int] = {}
        for p in sorted(set(self._platform)):
            mask = parr == p
            pk = self._peak_concurrency(start[mask], finish[mask])
            if pk > cfg.capacity(p):
                return None
            peaks[p] = pk
        return SlotSchedule(self._makespan, start, finish, peaks, 0.0)

    def _slot_schedule_pool(self, cfg: SlotConfig) -> SlotSchedule:
        """Single-pool FIFO list schedule for the (default-config) case where
        every per-platform cap is >= the global cap, so only the global cap
        can ever bind: ready tasks form one index-ordered heap and each
        launch is O(log n) — no per-launch scan across platform queues."""
        n = self.n
        indeg = [len(p) for p in self.preds]
        ready = [i for i in range(n) if indeg[i] == 0]
        heapq.heapify(ready)
        in_use = {p: 0 for p in set(self._platform)}
        peak = dict(in_use)
        ready_at = [0.0] * n
        start = np.zeros(n)
        finish = np.zeros(n)
        running: list[tuple[float, int]] = []
        global_in_use = 0
        t = 0.0
        wait = 0.0
        n_done = 0
        dur, plat, succs = self._dur, self._platform, self.succs
        while n_done < n:
            while ready and global_in_use < cfg.max_concurrent:
                i = heapq.heappop(ready)
                p = plat[i]
                start[i] = t
                finish[i] = t + dur[i]
                wait += t - ready_at[i]
                u = in_use[p] + 1
                in_use[p] = u
                if u > peak[p]:
                    peak[p] = u
                global_in_use += 1
                heapq.heappush(running, (finish[i], i))
            if not running:
                raise RuntimeError("slot schedule stalled (cycle?)")
            t, i = heapq.heappop(running)
            while True:
                in_use[plat[i]] -= 1
                global_in_use -= 1
                n_done += 1
                for s in succs[i]:
                    indeg[s] -= 1
                    if indeg[s] == 0:
                        ready_at[s] = t
                        heapq.heappush(ready, s)
                if running and running[0][0] <= t:
                    _, i = heapq.heappop(running)
                else:
                    break
        return SlotSchedule(float(finish.max()), start, finish, peak, wait)
