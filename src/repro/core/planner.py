"""DAG-level cost/deadline-aware run planner.

``DynamicClientFactory.choose`` is a per-task greedy argmin: it scores each
(asset, partition) in isolation, so under time pressure it happily pays the
premium surcharge for *every* task even though only the critical path decides
the makespan.  The planner fixes that with a global pass over the task DAG:

1. price every task on every feasible platform in one vectorized
   ``CostModel.estimate_batch`` call (expected cost with retries, roofline
   duration),
2. build the greedy baseline the factory would have produced; its
   **slot-aware** makespan becomes the default deadline, so a plan is never
   slower than greedy *as executed* (finite per-platform slots, shared with
   the coordinator via ``SlotConfig``),
3. start from the cheapest feasible assignment and *upgrade* critical-path
   tasks — batched best seconds-saved-per-dollar rounds with one schedule
   pass per round — until the target is met, then a slot-aware refinement
   loop buys down residual contention,
4. run a batched *downgrade* pass: off-path tasks move to cheaper platforms
   whenever the increase provably fits their slack; each trial is an O(cone)
   incremental retime (``ScheduleEngine.try_duration``), not a full
   reschedule,
5. check ``Objective.budget_usd`` / ``Objective.deadline_s`` and mark the
   plan infeasible (with a proof-style reason when even the cheapest/fastest
   assignment cannot satisfy the constraint).

Candidate selection tie-breaks are deterministic — stable sort on
(score, platform, key) — so the same DAG yields byte-identical plans across
runs and hash seeds.

**Cache-aware planning**: constructed with a ``MaterializationStore``, the
planner resolves per-(asset, partition) staleness first and plans only the
*stale cone* — fresh tasks are priced at $0 / ~0s on the pseudo-platform
``"cached"`` and never enter the schedule, so they can never occupy a
platform slot and a warm-cache re-run's plan collapses to the work that is
actually stale.  Staleness resolution is pessimistic (a stale upstream
poisons its consumers), which means the stale cone is upward-closed and the
reduced DAG needs no edge contraction.

The result is a ``RunPlan`` mapping every (asset, partition) to a
``PlannedChoice``; ``RunCoordinator.materialize(plan=...)`` consumes it and
falls back to the greedy factory on failover/deny.  ``targets`` everywhere
accepts an ``AssetSelection`` (or the legacy ``list[str]`` / a CLI
selection string — see ``core/selection.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.assets import AssetGraph
from repro.core.costmodel import CostEstimate
from repro.core.factory import DynamicClientFactory, Objective
from repro.core.schedule import (CRITICAL_EPS, ScheduleEngine, SlotConfig,
                                 SlotSchedule, task_dag)
from repro.core.selection import AssetSelection
from repro.core.store import MaterializationStore, resolve_staleness

TaskKey = tuple[str, str]  # (asset, partition)


@dataclasses.dataclass(frozen=True)
class PlannedChoice:
    """Final platform assignment for one (asset, partition) task."""

    asset: str
    partition: str
    platform: str
    estimate: CostEstimate
    expected_cost_usd: float  # retry-aware (cost / P(success))
    critical: bool = False
    slack_s: float = 0.0


@dataclasses.dataclass
class _Candidates:
    """Vectorized per-asset pricing shared by every partition of an asset."""

    assets: list[str]  # unique asset names (row order)
    platforms: list[str]  # column order: sorted platform names
    cost: np.ndarray  # [n_assets, n_platforms] expected USD, inf = excluded
    dur: np.ndarray  # [n_assets, n_platforms] seconds, inf = excluded
    #: what the *schedule* sees: == ``dur`` normally, or the rework-aware
    #: ``sched_duration_s`` under preemption-aware planning (failures and
    #: preemptions stretch the timeline, not just the expected cost)
    sched: np.ndarray = None
    rows: np.ndarray = None  # [n_tasks] task -> asset row
    #: CostEstimate component columns (same [n_assets, n_platforms] layout)
    #: so final choices are assembled without per-task scalar ``estimate``
    compute_s: np.ndarray = None
    base_usd: np.ndarray = None
    surcharge_usd: np.ndarray = None
    storage_usd: np.ndarray = None

    def estimate(self, row: int, col: int) -> CostEstimate:
        """Re-assemble the scalar ``CostEstimate`` for one priced cell."""
        return CostEstimate(
            platform=self.platforms[col],
            duration_s=float(self.dur[row, col]),
            compute_s=float(self.compute_s[row, col]),
            base_usd=float(self.base_usd[row, col]),
            surcharge_usd=float(self.surcharge_usd[row, col]),
            storage_usd=float(self.storage_usd[row, col]))


@dataclasses.dataclass
class RunPlan:
    objective: Objective
    choices: dict[TaskKey, PlannedChoice]
    predicted_cost_usd: float
    predicted_makespan_s: float  # slot-aware when planned with a SlotConfig
    greedy_cost_usd: float
    greedy_makespan_s: float
    feasible: bool = True
    reason: str = ""
    iterations: int = 0
    slot_config: SlotConfig | None = None
    platform_peaks: dict[str, int] = dataclasses.field(default_factory=dict)
    pert_makespan_s: float = 0.0  # infinite-width lower bound
    slot_wait_s: float = 0.0  # total time tasks sat ready-but-queued
    cached_tasks: int = 0  # fresh-in-store tasks priced at ~0 ("cached")

    @property
    def stale_tasks(self) -> int:
        return len(self.choices) - self.cached_tasks

    def choice(self, asset: str, partition: str) -> PlannedChoice | None:
        return self.choices.get((asset, partition))

    @property
    def cost_delta_vs_greedy(self) -> float:
        return self.predicted_cost_usd - self.greedy_cost_usd

    @property
    def makespan_delta_vs_greedy(self) -> float:
        return self.predicted_makespan_s - self.greedy_makespan_s

    def table(self, max_rows: int = 50) -> str:
        """Per-task assignment table plus predicted totals vs greedy.

        Beyond ``max_rows`` tasks the per-task listing is truncated and a
        per-(asset, platform) summary footer is printed instead — partitioned
        DAGs stay readable."""
        hdr = (f"{'task':<34} {'platform':<14} {'exp_usd':>9} "
               f"{'dur_h':>7} {'slack_h':>8} crit")
        lines = [hdr, "-" * len(hdr)]
        ordered = sorted(self.choices.items())
        truncated = max_rows is not None and len(ordered) > max_rows
        shown = ordered[:max_rows] if truncated else ordered
        for (a, p), c in shown:
            lines.append(
                f"{a + '[' + p + ']':<34} {c.platform:<14} "
                f"{c.expected_cost_usd:>9.2f} "
                f"{c.estimate.duration_s / 3600.0:>7.2f} "
                f"{c.slack_s / 3600.0:>8.2f} {'*' if c.critical else ''}")
        if truncated:
            lines.append(f"... ({len(ordered) - max_rows} more tasks; "
                         f"per-asset/platform summary below)")
            agg: dict[tuple[str, str], tuple[int, float, float]] = {}
            for (a, _p), c in ordered:
                n, usd, crit = agg.get((a, c.platform), (0, 0.0, 0))
                agg[(a, c.platform)] = (n + 1, usd + c.expected_cost_usd,
                                        crit + (1 if c.critical else 0))
            lines.append("-" * len(hdr))
            lines.append(f"{'asset @ platform':<49} {'tasks':>6} "
                         f"{'exp_usd':>9} {'crit':>5}")
            for (a, plat), (n, usd, crit) in sorted(agg.items()):
                lines.append(f"{a + ' @ ' + plat:<49} {n:>6} {usd:>9.2f} "
                             f"{crit:>5}")
        lines.append("-" * len(hdr))
        if self.cached_tasks:
            lines.append(
                f"cached:   {self.cached_tasks} of {len(self.choices)} tasks "
                f"fresh in store ($0, no slots); {self.stale_tasks} planned")
        lines.append(
            f"planned: ${self.predicted_cost_usd:.2f} / "
            f"{self.predicted_makespan_s / 3600.0:.2f} h   "
            f"greedy: ${self.greedy_cost_usd:.2f} / "
            f"{self.greedy_makespan_s / 3600.0:.2f} h   "
            f"delta: ${self.cost_delta_vs_greedy:+.2f} / "
            f"{self.makespan_delta_vs_greedy / 3600.0:+.2f} h")
        if self.slot_config is not None and self.platform_peaks:
            parts = []
            for name in sorted(self.platform_peaks):
                peak = self.platform_peaks[name]
                cap = self.slot_config.capacity(name)
                parts.append(f"{name} {peak}/{cap}"
                             + ("!" if peak >= cap else ""))
            lines.append(
                f"slots:    {'  '.join(parts)}   "
                f"(queued {self.slot_wait_s / 3600.0:.2f} task-h; "
                f"critical-path bound {self.pert_makespan_s / 3600.0:.2f} h)")
        if self.objective.budget_usd is not None:
            lines.append(f"budget:   ${self.objective.budget_usd:.2f} "
                         f"({'OK' if self.feasible else 'VIOLATED'})")
        if self.objective.deadline_s is not None:
            lines.append(f"deadline: {self.objective.deadline_s / 3600.0:.2f} h"
                         f" ({'OK' if self.feasible else 'VIOLATED'})")
        if not self.feasible:
            lines.append(f"INFEASIBLE: {self.reason}")
        return "\n".join(lines)


class RunPlanner:
    """Global (asset, partition) -> platform assignment under an Objective.

    ``slots`` defaults to the coordinator's ``SlotConfig`` so predictions
    account for finite per-platform concurrency; pass ``slots=None`` for the
    infinite-width (pure critical-path) relaxation.

    ``store`` (optional) enables cache-aware planning: tasks fresh in the
    ``MaterializationStore`` are excluded from the schedule and priced at
    ``CostEstimate.cached()`` — see the module docstring.
    """

    def __init__(self, graph: AssetGraph, factory: DynamicClientFactory,
                 max_iterations: int | None = None,
                 slots: SlotConfig | None = SlotConfig(),
                 store: MaterializationStore | None = None,
                 preemption_aware: bool = False):
        self.graph = graph
        self.factory = factory
        #: hard cap on optimization moves per plan; None (default) scales
        #: with DAG size — moves are O(cone) now, so a 10k-task DAG can
        #: afford 10k of them (the legacy planner paid a full O(n)
        #: reschedule per move and capped at 1000 regardless)
        self.max_iterations = max_iterations
        self.slots = slots
        self.store = store
        #: schedule on rework-aware durations (``sched_duration_s``): each
        #: task's timeline slot is stretched by expected retry rework on its
        #: platform, so flaky-platform assignments pay in *makespan*, not
        #: just expected cost.  Off by default — nominal durations keep the
        #: planner's makespan prediction aligned with a coordinator replay
        #: of the no-failure case; the adaptive coordinator turns it on.
        self.preemption_aware = preemption_aware

    # ------------------------------------------------------------ pricing
    def _candidates(self, keys: list[TaskKey]) -> _Candidates:
        """Vectorized feasible per-platform pricing; honors ``platform_hint``
        pins.  Estimates depend on (asset, platform) only, so partitions of
        one asset share a single priced row."""
        assets: list[str] = []
        row_of: dict[str, int] = {}
        for name, _part in keys:
            if name not in row_of:
                row_of[name] = len(assets)
                assets.append(name)
        platforms = sorted(self.factory.catalog)
        specs = [self.graph[a] for a in assets]
        batch = self.factory.cost_model.estimate_batch(
            specs, [self.factory.catalog[p] for p in platforms])
        cost = batch["expected_usd"].copy()
        dur = batch["duration_s"].copy()
        sched = (batch["sched_duration_s"].copy() if self.preemption_aware
                 else dur)
        for i, spec in enumerate(specs):
            # a hint naming a platform outside the catalog (e.g. evicted by
            # an open circuit breaker) is ignored rather than made
            # unsatisfiable
            if spec.platform_hint and spec.platform_hint in platforms:
                for j, pname in enumerate(platforms):
                    if pname != spec.platform_hint:
                        cost[i, j] = dur[i, j] = np.inf
                        sched[i, j] = np.inf
            if not np.isfinite(cost[i]).any():
                raise RuntimeError(
                    f"no feasible platform for asset {spec.name!r}")
        rows = np.asarray([row_of[name] for name, _ in keys], dtype=np.int64)
        return _Candidates(assets, platforms, cost, dur, sched=sched,
                           rows=rows,
                           compute_s=batch["compute_s"],
                           base_usd=batch["base_usd"],
                           surcharge_usd=batch["surcharge_usd"],
                           storage_usd=batch["storage_usd"])

    # ----------------------------------------------------- assignments
    @staticmethod
    def _argmin_rows(primary: np.ndarray, secondary: np.ndarray) -> np.ndarray:
        """Per-row argmin of (primary, secondary, column) — deterministic
        lexicographic tie-breaking (columns are sorted platform names).
        Vectorized: mask the primary ties, break them on the secondary, and
        let ``argmax`` on the surviving mask pick the lowest column."""
        p_min = primary.min(axis=1, keepdims=True)
        tie = primary == p_min
        sec = np.where(tie, secondary, np.inf)
        tie &= sec == sec.min(axis=1, keepdims=True)
        return tie.argmax(axis=1).astype(np.int64)

    def _greedy_cols(self, cand: _Candidates, obj: Objective) -> np.ndarray:
        """What per-task ``factory.choose`` would do — the baseline."""
        tv = obj.time_value_usd_per_hour
        with np.errstate(invalid="ignore"):
            # 0 * inf = nan on excluded cells; force them back to +inf
            score = np.where(np.isfinite(cand.cost),
                             cand.cost + tv * (cand.dur / 3600.0), np.inf)
        return self._argmin_rows(score, cand.cost)

    # ----------------------------------------------------------------- api
    def plan(self, targets: "AssetSelection | str | list[str] | None" = None,
             objective: Objective | None = None,
             force: bool = False,
             exclude: "set[TaskKey] | None" = None) -> RunPlan:
        """``exclude`` drops (asset, partition) tasks from the plan — the
        mid-run replan path passes everything already done or in flight.
        The set must be predecessor-closed (every predecessor of an excluded
        task is itself excluded), which done+running sets are by
        construction: a task only launches once its deps finished."""
        obj = objective or self.factory.objective
        names = AssetSelection.coerce(targets).resolve(self.graph)
        keys, preds = task_dag(self.graph, names)
        if exclude:
            keys = [k for k in keys if k not in exclude]
            preds = {k: [p for p in preds[k] if p not in exclude]
                     for k in keys}
        cached_keys: list[TaskKey] = []
        if self.store is not None and not force:
            staleness = resolve_staleness(self.graph, self.store, names)
            fresh = {k for k in keys if staleness[k].fresh}
            if fresh:
                # pessimistic resolution makes the stale cone upward-closed
                # (every predecessor of a stale task is itself stale or
                # absent), so dropping fresh tasks and filtering their edges
                # out of ``preds`` is an exact DAG restriction
                cached_keys = [k for k in keys if k in fresh]
                keys = [k for k in keys if k not in fresh]
                preds = {k: [p for p in preds[k] if p not in fresh]
                         for k in keys}
        cand = self._candidates(keys)
        engine = ScheduleEngine(keys, preds, self.slots)
        rows = cand.rows
        plat_arr = np.asarray(cand.platforms)

        def load(cols: np.ndarray) -> float:
            """Full schedule pass for an assignment; returns PERT makespan.
            Schedules on ``cand.sched`` (== ``dur`` unless preemption-aware
            planning inflated it with expected rework)."""
            return engine.load(cand.sched[rows, cols], plat_arr[cols])

        def slot_ms() -> SlotSchedule:
            return engine.slot_schedule()

        def total_cost(cols: np.ndarray) -> float:
            return float(cand.cost[rows, cols].sum()) if len(rows) else 0.0

        # greedy baseline (slot-aware: what greedy costs *as executed*)
        greedy_cols = self._greedy_cols(cand, obj)[rows] \
            if len(rows) else np.zeros(0, dtype=np.int64)
        load(greedy_cols)
        greedy_sched = slot_ms()
        greedy_ms = greedy_sched.makespan_s
        greedy_cost = total_cost(greedy_cols)

        # a plan must never be slower than greedy; a deadline tightens that
        target = greedy_ms
        if obj.deadline_s is not None:
            target = min(target, obj.deadline_s)

        iters = 0
        budget = (self.max_iterations if self.max_iterations is not None
                  else max(1000, 2 * len(keys)))
        feasible, reason = True, ""

        # provable lower bounds first: the infinite-width makespan of the
        # fastest assignment lower-bounds any schedule under any slots, and
        # the cheapest assignment lower-bounds any plan's cost.
        fastest_cols = self._argmin_rows(cand.sched, cand.cost)[rows] \
            if len(rows) else np.zeros(0, dtype=np.int64)
        fastest_pert = load(fastest_cols)
        cheapest_cols = self._argmin_rows(cand.cost, cand.dur)[rows] \
            if len(rows) else np.zeros(0, dtype=np.int64)
        min_cost = total_cost(cheapest_cols)
        if obj.deadline_s is not None and fastest_pert > obj.deadline_s:
            feasible = False
            reason = (f"deadline {obj.deadline_s:.0f}s infeasible: even the "
                      f"fastest assignment needs {fastest_pert:.0f}s on the "
                      f"critical path alone")
        if obj.budget_usd is not None and min_cost > obj.budget_usd:
            feasible = False
            reason = (reason + "; " if reason else "") + (
                f"budget ${obj.budget_usd:.2f} infeasible: even the cheapest "
                f"assignment costs ${min_cost:.2f}")

        # 1) start cheap, 2) buy back time on the critical path — batched
        # best-rate rounds, one full schedule pass per round instead of one
        # per candidate trial
        cols = cheapest_cols.copy()
        pert = load(cols)
        while pert > target * (1 + 1e-9) and iters < budget:
            applied = self._upgrade_round(engine, cand, cols,
                                          pert - target)
            if not applied:
                break
            iters += applied
            pert = load(cols)

        # 2b) slot-aware refinement: the PERT bound is met (or unmeetable)
        # but finite slots may still queue work past the target
        sched = slot_ms()
        greedy_meets = greedy_ms <= target * (1 + 1e-9)
        if sched.makespan_s > target * (1 + 1e-9) and greedy_meets \
                and sched.makespan_s > 1.5 * max(pert, 1e-9):
            # throughput-bound regime: the binding limit is slot width, not
            # the critical path — migrating ~n tasks batch by batch costs
            # more planning time than it saves, so start from greedy (which
            # meets the target by definition) and let the downgrade pass
            # claw cost back inside the slot envelope
            cols = greedy_cols.copy()
            pert = load(cols)
            sched = greedy_sched  # identical assignment: reuse its schedule
        else:
            # latency-bound residual: keep buying speed / shifting load off
            # the saturated platform, one schedule pass per round, until the
            # target is met or progress stalls
            rounds = 0
            while sched.makespan_s > target * (1 + 1e-9) \
                    and iters < budget and rounds < 48:
                applied = self._contention_round(engine, cand, cols, sched,
                                                 budget - iters,
                                                 greedy_meets=greedy_meets)
                if not applied:
                    break
                iters += applied
                load(cols)
                prev_ms = sched.makespan_s
                sched = slot_ms()
                rounds += 1
                if sched.makespan_s > prev_ms * (1 - 1e-3):
                    break  # stalled: the fallback below takes over

        if sched.makespan_s > target * (1 + 1e-9):
            if obj.deadline_s is not None and feasible:
                feasible = False
                reason = (f"deadline {obj.deadline_s:.0f}s unmet: best "
                          f"achievable makespan {sched.makespan_s:.0f}s")
            # never return a plan slower than greedy
            if sched.makespan_s > greedy_ms:
                cols = greedy_cols.copy()
                load(cols)
                sched = greedy_sched

        # 3) spend slack: batched downgrade pass — off-path tasks take the
        # cheapest platform whose extra duration provably fits their slack;
        # each trial is an O(cone) incremental retime, slack re-derived
        # lazily once per round, slot-validated in chunks
        slot_cap = max(target, sched.makespan_s)
        moved = self._downgrade(engine, cand, cols, budget - iters,
                                slot_cap, load)
        iters += moved
        if moved:
            sched = slot_ms()

        cost = total_cost(cols)
        # dominance guard: when greedy itself meets the target, never ship a
        # plan that costs more than greedy
        if cost > greedy_cost + 1e-9 and greedy_ms <= target * (1 + 1e-9):
            cols = greedy_cols.copy()
            load(cols)
            sched = greedy_sched
            cost = greedy_cost

        if obj.budget_usd is not None and cost > obj.budget_usd and feasible:
            feasible = False
            reason = (f"budget ${obj.budget_usd:.2f} unmet at deadline: best "
                      f"plan costs ${cost:.2f}")

        slack = engine.slack()
        crit = engine.critical_mask()
        # estimates depend on (asset row, platform col) only: reassemble one
        # CostEstimate per priced cell from the batch columns — no scalar
        # ``estimate`` calls even when every task is its own asset
        est_cache: dict[tuple[int, int], CostEstimate] = {}
        choices: dict[TaskKey, PlannedChoice] = {}
        for t, tk in enumerate(keys):
            col = int(cols[t])
            ck = (int(rows[t]), col)
            est = est_cache.get(ck)
            if est is None:
                est = est_cache[ck] = cand.estimate(*ck)
            choices[tk] = PlannedChoice(
                asset=tk[0], partition=tk[1],
                platform=cand.platforms[col],
                estimate=est,
                expected_cost_usd=float(cand.cost[rows[t], col]),
                critical=bool(crit[t]), slack_s=float(slack[t]))
        for tk in cached_keys:
            choices[tk] = PlannedChoice(
                asset=tk[0], partition=tk[1], platform="cached",
                estimate=CostEstimate.cached(), expected_cost_usd=0.0,
                critical=False, slack_s=0.0)
        return RunPlan(
            objective=obj, choices=choices, predicted_cost_usd=cost,
            predicted_makespan_s=sched.makespan_s,
            greedy_cost_usd=greedy_cost,
            greedy_makespan_s=greedy_ms,
            feasible=feasible, reason=reason, iterations=iters,
            slot_config=self.slots,
            platform_peaks=dict(sched.peak_in_use),
            pert_makespan_s=engine.makespan_s,
            slot_wait_s=sched.wait_s_total,
            cached_tasks=len(cached_keys))

    # ------------------------------------------------------ upgrade rounds
    def _upgrade_round(self, engine: ScheduleEngine, cand: _Candidates,
                       cols: np.ndarray, gap_s: float) -> int:
        """Apply the best seconds-saved-per-dollar moves on critical tasks
        until their combined saving covers ``gap_s``.  Savings on parallel
        critical branches are not additive, so the next round's schedule
        pass re-measures; rounds converge geometrically in practice."""
        crit = engine.critical_mask()
        moves = self._rank_moves(cand, cols, crit, engine.durations())
        if not moves:
            return 0
        applied = 0
        saved = 0.0
        for _rate, _plat, t, col, save in moves:
            cols[t] = col
            applied += 1
            saved += save
            if saved >= gap_s:
                break
        return applied

    def _contention_round(self, engine: ScheduleEngine, cand: _Candidates,
                          cols: np.ndarray, sched: SlotSchedule,
                          remaining: int, greedy_meets: bool) -> int:
        """One slot-refinement round: upgrade the best-rate moves among
        tasks that are PERT-critical or sitting on the most-loaded platform
        when it is saturated.  Batch size scales with the number of eligible
        moves so rebalancing a 10k-task backlog doesn't take 10k rounds.
        When the rebalance provably cannot fit the remaining move budget and
        greedy already meets the target, bail out — the greedy fallback is
        cheaper than grinding through a doomed refinement."""
        dur = engine.durations()
        plats = engine.platforms()
        load_by: dict[str, float] = {}
        for i, p in enumerate(plats):
            load_by[p] = load_by.get(p, 0.0) + dur[i]
        hot = max(sorted(load_by), key=lambda p: load_by[p]) if load_by else ""
        mask = engine.critical_mask().copy()
        if hot and self.slots is not None and \
                sched.peak_in_use.get(hot, 0) >= self.slots.capacity(hot):
            plat_arr = np.asarray(plats)
            mask |= plat_arr == hot
        moves = self._rank_moves(cand, cols, mask, dur)
        if not moves or (greedy_meets and len(moves) > remaining):
            return 0
        batch = min(max(1, len(moves) // 8), remaining)
        for _rate, _plat, t, col, _save in moves[:batch]:
            cols[t] = col
        return batch

    @staticmethod
    def _rank_moves(cand: _Candidates, cols: np.ndarray,
                    mask: np.ndarray, dur: np.ndarray) -> list[
                        tuple[float, str, int, int, float]]:
        """Deterministically ranked speed-up moves for masked tasks, one
        best move per task: sorted by (rate desc, platform, task index) —
        task index is topological, so ordering is stable across runs and
        hash seeds.  Each move is (neg_rate, platform, task, col, saved_s).
        Fully vectorized: one numpy pass over tasks x platforms."""
        idx = np.flatnonzero(mask)
        if len(idx) == 0:
            return []
        r = cand.rows[idx]
        cur_c = cand.cost[r, cols[idx]]
        save = dur[idx][:, None] - cand.sched[r]  # [k, m]
        dcost = cand.cost[r] - cur_c[:, None]
        with np.errstate(invalid="ignore"):
            rate = save / np.maximum(dcost, 1e-9)
            valid = (save > 0) & np.isfinite(cand.cost[r])
        rate = np.where(valid, rate, -np.inf)
        # first argmax = lowest column index = alphabetically-first platform,
        # matching the (rate desc, platform) tie-break
        best = np.argmax(rate, axis=1)
        k = np.arange(len(idx))
        brate = rate[k, best]
        keep = np.isfinite(brate)
        if not keep.any():
            return []
        idx, best, brate = idx[keep], best[keep], brate[keep]
        bsave = save[k[keep], best]
        order = np.lexsort((idx, best, -brate))
        return [(float(-brate[i]), cand.platforms[best[i]], int(idx[i]),
                 int(best[i]), float(bsave[i])) for i in order]

    # --------------------------------------------------------- downgrades
    def _downgrade(self, engine: ScheduleEngine, cand: _Candidates,
                   cols: np.ndarray, budget: int, slot_cap: float,
                   reload) -> int:
        """Batched slack-spending: for every task (largest slack first, then
        key — deterministic), take the cheapest platform whose extra
        duration fits the task's current slack.  Each acceptance is an
        incremental O(cone) retime; slack is recomputed lazily once per
        round — the legacy planner paid a full O(n) reschedule per trial.

        The PERT cap proves accepted moves never stretch the critical path;
        finite slots can still (rarely) cascade a longer task into a later
        queue, so batches are slot-validated in chunks: a chunk that pushes
        the slot makespan past ``slot_cap`` is rolled back and the pass
        stops at the last good checkpoint."""
        if budget <= 0 or engine.n == 0:
            return 0
        rows = cand.rows
        cap = engine.makespan_s * (1 + 1e-12)
        assets_arr = np.asarray([k[0] for k in engine.keys])
        parts_arr = np.asarray([k[1] for k in engine.keys])
        chunk = max(32, engine.n // 16)
        snapshot = cols.copy()
        accepted = 0  # since last slot validation
        iters = 0

        def validate() -> bool:
            """Slot-check the pending chunk; roll back to the checkpoint on
            regression (uncounting the discarded moves).  Returns False to
            stop the pass."""
            nonlocal accepted, snapshot, iters
            if accepted == 0:
                return True
            sched = engine.slot_schedule()
            if sched.makespan_s > slot_cap * (1 + 1e-9):
                cols[:] = snapshot
                reload(cols)
                iters -= accepted  # rolled back: not part of the plan
                accepted = 0
                return False
            snapshot = cols.copy()
            accepted = 0
            return True

        # cheaper-platform options depend only on (asset row, current col):
        # memoize so 10k partitions of one asset don't re-sort 10k times
        opt_cache: dict[tuple[int, int], list[int]] = {}

        def options(r: int, cur_col: int) -> list[int]:
            ck = (int(r), cur_col)
            out = opt_cache.get(ck)
            if out is None:
                cur_c = cand.cost[r, cur_col]
                out = sorted(
                    (j for j in range(len(cand.platforms))
                     if np.isfinite(cand.cost[r, j]) and
                     cand.cost[r, j] < cur_c),
                    key=lambda j: (cand.cost[r, j], cand.sched[r, j], j))
                opt_cache[ck] = out
            return out

        improved = True
        alive = True
        while improved and alive and iters < budget:
            improved = False
            slack = engine.slack()
            order = np.lexsort((parts_arr, assets_arr, -slack))
            for t in order:
                if iters >= budget:
                    break
                t = int(t)
                r = rows[t]
                cur_col = int(cols[t])
                cur_d = cand.sched[r, cur_col]
                for j in options(r, cur_col):
                    extra = cand.sched[r, j] - cur_d
                    if extra > slack[t] * (1 + 1e-12) + 1e-9:
                        continue  # cannot fit even in this task's slack
                    ms, undo = engine.try_duration(
                        t, cand.sched[r, j], cand.platforms[j])
                    if ms <= cap:
                        cols[t] = j
                        improved = True
                        iters += 1
                        accepted += 1
                        if accepted >= chunk and not validate():
                            alive = False
                        break
                    undo()
                if not alive:
                    break
        if alive:
            validate()
        return iters


def plan_run(graph: AssetGraph, factory: DynamicClientFactory,
             targets: "AssetSelection | str | list[str] | None" = None,
             objective: Objective | None = None,
             slots: SlotConfig | None = SlotConfig(),
             store: MaterializationStore | None = None,
             force: bool = False) -> RunPlan:
    """One-shot convenience wrapper around ``RunPlanner``."""
    return RunPlanner(graph, factory, slots=slots, store=store).plan(
        targets, objective, force=force)


# re-exported for backwards compatibility with PR-2 imports
_CRITICAL_EPS = CRITICAL_EPS
