"""DAG-level cost/deadline-aware run planner.

``DynamicClientFactory.choose`` is a per-task greedy argmin: it scores each
(asset, partition) in isolation, so under time pressure it happily pays the
premium surcharge for *every* task even though only the critical path decides
the makespan.  The planner fixes that with a global pass over the task DAG:

1. price every task on every feasible platform (expected cost with retries,
   roofline duration),
2. build the greedy baseline the factory would have produced (its makespan
   becomes the default deadline, so a plan is never slower than greedy),
3. start from the cheapest feasible assignment and *upgrade* critical-path
   tasks — picking the move with the best seconds-saved-per-dollar — until
   the deadline target is met,
4. run a slack-based *downgrade* pass: off-path tasks move to cheaper
   platforms whenever the schedule shows the makespan does not grow,
5. check ``Objective.budget_usd`` / ``Objective.deadline_s`` and mark the
   plan infeasible (with a proof-style reason when even the cheapest/fastest
   assignment cannot satisfy the constraint).

The result is a ``RunPlan`` mapping every (asset, partition) to a
``PlannedChoice``; ``RunCoordinator.materialize(plan=...)`` consumes it and
falls back to the greedy factory on failover/deny.
"""
from __future__ import annotations

import dataclasses

from repro.core.assets import AssetGraph
from repro.core.costmodel import CostEstimate
from repro.core.factory import DynamicClientFactory, Objective
from repro.core.partitions import dep_partition_keys, partition_keys

TaskKey = tuple[str, str]  # (asset, partition)

#: slack below this fraction of the makespan counts as "on the critical path"
_CRITICAL_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class PlannedChoice:
    """Final platform assignment for one (asset, partition) task."""

    asset: str
    partition: str
    platform: str
    estimate: CostEstimate
    expected_cost_usd: float  # retry-aware (cost / P(success))
    critical: bool = False
    slack_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class _Candidate:
    platform: str
    estimate: CostEstimate
    cost_usd: float  # expected, retry-aware
    duration_s: float


@dataclasses.dataclass
class _Schedule:
    makespan_s: float
    finish: dict[TaskKey, float]
    slack: dict[TaskKey, float]

    def critical(self, key: TaskKey) -> bool:
        return self.slack[key] <= _CRITICAL_EPS * max(self.makespan_s, 1.0)


@dataclasses.dataclass
class RunPlan:
    objective: Objective
    choices: dict[TaskKey, PlannedChoice]
    predicted_cost_usd: float
    predicted_makespan_s: float
    greedy_cost_usd: float
    greedy_makespan_s: float
    feasible: bool = True
    reason: str = ""
    iterations: int = 0

    def choice(self, asset: str, partition: str) -> PlannedChoice | None:
        return self.choices.get((asset, partition))

    @property
    def cost_delta_vs_greedy(self) -> float:
        return self.predicted_cost_usd - self.greedy_cost_usd

    @property
    def makespan_delta_vs_greedy(self) -> float:
        return self.predicted_makespan_s - self.greedy_makespan_s

    def table(self) -> str:
        """Per-task assignment table plus predicted totals vs greedy."""
        hdr = (f"{'task':<34} {'platform':<14} {'exp_usd':>9} "
               f"{'dur_h':>7} {'slack_h':>8} crit")
        lines = [hdr, "-" * len(hdr)]
        for (a, p), c in sorted(self.choices.items()):
            lines.append(
                f"{a + '[' + p + ']':<34} {c.platform:<14} "
                f"{c.expected_cost_usd:>9.2f} "
                f"{c.estimate.duration_s / 3600.0:>7.2f} "
                f"{c.slack_s / 3600.0:>8.2f} {'*' if c.critical else ''}")
        lines.append("-" * len(hdr))
        lines.append(
            f"planned: ${self.predicted_cost_usd:.2f} / "
            f"{self.predicted_makespan_s / 3600.0:.2f} h   "
            f"greedy: ${self.greedy_cost_usd:.2f} / "
            f"{self.greedy_makespan_s / 3600.0:.2f} h   "
            f"delta: ${self.cost_delta_vs_greedy:+.2f} / "
            f"{self.makespan_delta_vs_greedy / 3600.0:+.2f} h")
        if self.objective.budget_usd is not None:
            lines.append(f"budget:   ${self.objective.budget_usd:.2f} "
                         f"({'OK' if self.feasible else 'VIOLATED'})")
        if self.objective.deadline_s is not None:
            lines.append(f"deadline: {self.objective.deadline_s / 3600.0:.2f} h"
                         f" ({'OK' if self.feasible else 'VIOLATED'})")
        if not self.feasible:
            lines.append(f"INFEASIBLE: {self.reason}")
        return "\n".join(lines)


class RunPlanner:
    """Global (asset, partition) -> platform assignment under an Objective."""

    def __init__(self, graph: AssetGraph, factory: DynamicClientFactory,
                 max_iterations: int = 1000):
        self.graph = graph
        self.factory = factory
        self.max_iterations = max_iterations

    # ------------------------------------------------------------- task DAG
    def _tasks(self, targets: list[str] | None) -> tuple[
            list[TaskKey], dict[TaskKey, list[TaskKey]]]:
        """Topologically ordered task keys + predecessor edges."""
        order = self.graph.topo_order(targets)
        keys: list[TaskKey] = []
        preds: dict[TaskKey, list[TaskKey]] = {}
        for name in order:
            spec = self.graph[name]
            for key in partition_keys(spec.partitions):
                tk = (name, key)
                keys.append(tk)
                preds[tk] = [
                    (d, dk) for d in spec.deps
                    for dk in dep_partition_keys(
                        self.graph[d].partitions, key)]
        return keys, preds

    def _candidates(self, keys: list[TaskKey]) -> dict[
            TaskKey, list[_Candidate]]:
        """Feasible per-platform pricing; honors ``platform_hint`` pins.
        Estimates depend on (asset, platform) only, so partitions of one
        asset share a single priced candidate list."""
        cm = self.factory.cost_model
        by_asset: dict[str, list[_Candidate]] = {}
        out: dict[TaskKey, list[_Candidate]] = {}
        for name, _part in keys:
            if name not in by_asset:
                spec = self.graph[name]
                cands = []
                for pname, platform in self.factory.catalog.items():
                    if spec.platform_hint and pname != spec.platform_hint:
                        continue
                    est = cm.estimate(spec, platform)
                    if not est.feasible:
                        continue
                    cands.append(_Candidate(
                        pname, est,
                        cm.expected_cost_with_retries(est, platform),
                        est.duration_s))
                if not cands:
                    raise RuntimeError(
                        f"no feasible platform for asset {name!r}")
                by_asset[name] = cands
            out[(name, _part)] = by_asset[name]
        return out

    # ------------------------------------------------------------ schedule
    @staticmethod
    def _schedule(keys: list[TaskKey], preds: dict[TaskKey, list[TaskKey]],
                  durations: dict[TaskKey, float]) -> _Schedule:
        """Forward/backward critical-path pass (infinite-width PERT)."""
        finish: dict[TaskKey, float] = {}
        for tk in keys:  # keys are topo-ordered
            start = max((finish[p] for p in preds[tk]), default=0.0)
            finish[tk] = start + durations[tk]
        makespan = max(finish.values(), default=0.0)
        succs: dict[TaskKey, list[TaskKey]] = {tk: [] for tk in keys}
        for tk in keys:
            for p in preds[tk]:
                succs[p].append(tk)
        latest: dict[TaskKey, float] = {}
        for tk in reversed(keys):
            latest[tk] = min(
                (latest[s] - durations[s] for s in succs[tk]),
                default=makespan)
        slack = {tk: latest[tk] - finish[tk] for tk in keys}
        return _Schedule(makespan, finish, slack)

    # ------------------------------------------------------------- assigns
    @staticmethod
    def _greedy_assignment(cands: dict[TaskKey, list[_Candidate]],
                           objective: Objective) -> dict[TaskKey, _Candidate]:
        """What per-task ``factory.choose`` would do — the baseline."""
        tv = objective.time_value_usd_per_hour
        return {tk: min(cs, key=lambda c: c.cost_usd
                        + tv * c.duration_s / 3600.0)
                for tk, cs in cands.items()}

    @staticmethod
    def _cheapest_assignment(cands: dict[TaskKey, list[_Candidate]]) -> dict[
            TaskKey, _Candidate]:
        return {tk: min(cs, key=lambda c: (c.cost_usd, c.duration_s))
                for tk, cs in cands.items()}

    @staticmethod
    def _fastest_assignment(cands: dict[TaskKey, list[_Candidate]]) -> dict[
            TaskKey, _Candidate]:
        return {tk: min(cs, key=lambda c: (c.duration_s, c.cost_usd))
                for tk, cs in cands.items()}

    # ----------------------------------------------------------------- api
    def plan(self, targets: list[str] | None = None,
             objective: Objective | None = None) -> RunPlan:
        obj = objective or self.factory.objective
        keys, preds = self._tasks(targets)
        cands = self._candidates(keys)
        durations = lambda assign: {tk: c.duration_s  # noqa: E731
                                    for tk, c in assign.items()}
        total_cost = lambda assign: sum(  # noqa: E731
            c.cost_usd for c in assign.values())

        greedy = self._greedy_assignment(cands, obj)
        greedy_sched = self._schedule(keys, preds, durations(greedy))
        greedy_cost = total_cost(greedy)

        # a plan must never be slower than greedy; a deadline tightens that
        target_ms = greedy_sched.makespan_s
        if obj.deadline_s is not None:
            target_ms = min(target_ms, obj.deadline_s)

        iters = 0
        feasible, reason = True, ""

        # provable lower bounds first: if even the extreme assignment cannot
        # satisfy a constraint, no amount of reassignment will.
        fastest_ms = self._schedule(
            keys, preds, durations(self._fastest_assignment(cands))).makespan_s
        cheapest = self._cheapest_assignment(cands)
        min_cost = total_cost(cheapest)
        if obj.deadline_s is not None and fastest_ms > obj.deadline_s:
            feasible = False
            reason = (f"deadline {obj.deadline_s:.0f}s infeasible: even the "
                      f"fastest assignment needs {fastest_ms:.0f}s")
        if obj.budget_usd is not None and min_cost > obj.budget_usd:
            feasible = False
            reason = (reason + "; " if reason else "") + (
                f"budget ${obj.budget_usd:.2f} infeasible: even the cheapest "
                f"assignment costs ${min_cost:.2f}")

        # 1) start cheap, 2) buy back time on the critical path
        assign = dict(cheapest)
        sched = self._schedule(keys, preds, durations(assign))
        while sched.makespan_s > target_ms and iters < self.max_iterations:
            iters += 1
            best: tuple[float, TaskKey, _Candidate] | None = None
            for tk in keys:
                if not sched.critical(tk):
                    continue  # time-weighted moves only help on the path
                cur = assign[tk]
                for c in cands[tk]:
                    saved = cur.duration_s - c.duration_s
                    if saved <= 0:
                        continue
                    rate = saved / max(c.cost_usd - cur.cost_usd, 1e-9)
                    if best is None or rate > best[0]:
                        best = (rate, tk, c)
            if best is None:
                break  # no critical task can go faster
            assign[best[1]] = best[2]
            sched = self._schedule(keys, preds, durations(assign))

        if sched.makespan_s > target_ms * (1 + 1e-9):
            if obj.deadline_s is not None and feasible:
                feasible = False
                reason = (f"deadline {obj.deadline_s:.0f}s unmet: best "
                          f"achievable makespan {sched.makespan_s:.0f}s")
            # never return a plan slower than greedy
            if sched.makespan_s > greedy_sched.makespan_s:
                assign = dict(greedy)
                sched = self._schedule(keys, preds, durations(assign))

        # 3) spend slack: off-path tasks take the cheapest platform that
        # keeps the makespan at (or under) the target — cost-weighted scoring
        improved = True
        while improved and iters < self.max_iterations:
            improved = False
            for tk in sorted(keys, key=lambda k: -sched.slack[k]):
                cur = assign[tk]
                for c in sorted(cands[tk], key=lambda c: c.cost_usd):
                    if c.cost_usd >= cur.cost_usd:
                        break
                    if c.duration_s > cur.duration_s + sched.slack[tk]:
                        continue  # cannot fit even in this task's slack
                    trial = dict(assign)
                    trial[tk] = c
                    tsched = self._schedule(keys, preds, durations(trial))
                    if tsched.makespan_s <= max(sched.makespan_s, target_ms) \
                            * (1 + 1e-12):
                        assign, sched = trial, tsched
                        improved = True
                        iters += 1
                        break

        cost = total_cost(assign)
        if obj.budget_usd is not None and cost > obj.budget_usd and feasible:
            feasible = False
            reason = (f"budget ${obj.budget_usd:.2f} unmet at deadline: best "
                      f"plan costs ${cost:.2f}")

        choices = {
            tk: PlannedChoice(
                asset=tk[0], partition=tk[1], platform=c.platform,
                estimate=c.estimate, expected_cost_usd=c.cost_usd,
                critical=sched.critical(tk), slack_s=sched.slack[tk])
            for tk, c in assign.items()}
        return RunPlan(
            objective=obj, choices=choices, predicted_cost_usd=cost,
            predicted_makespan_s=sched.makespan_s,
            greedy_cost_usd=greedy_cost,
            greedy_makespan_s=greedy_sched.makespan_s,
            feasible=feasible, reason=reason, iterations=iters)


def plan_run(graph: AssetGraph, factory: DynamicClientFactory,
             targets: list[str] | None = None,
             objective: Objective | None = None) -> RunPlan:
    """One-shot convenience wrapper around ``RunPlanner``."""
    return RunPlanner(graph, factory).plan(targets, objective)
