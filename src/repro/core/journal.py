"""Write-ahead run journal: crash-consistent durability for the coordinator.

The paper's cost wins come from leaning on cheap, preemptible capacity —
which only pays off if a *run* survives the orchestrator itself dying, not
just individual task failures.  ``RunJournal`` is an append-only JSONL log
(one file per run) that the ``RunCoordinator`` writes at every task
lifecycle transition:

    BEGIN    run opened: targets, objective, force/cache flags
    LAUNCH   an attempt submitted to a platform (incl. speculative twins)
    BILL     an attempt's terminal money record (outcome rides along)
    SUCCESS  a materialization landed in the store (after ``put``)
    REPLAN   the adaptive loop adopted/rejected a mid-run replan
    RESUME   a crashed run was reopened by ``RunCoordinator.resume``
    FAIL     a task exhausted its retry budget (the run is about to raise)
    END      the run returned (ok flag)

Durability contract: every record is fsync'd before the coordinator acts on
it, each line carries a checksum of its own payload, and replay tolerates a
torn tail (a crash mid-write loses at most the record being written, never
the prefix).  Records are idempotency-keyed per (run, asset, partition,
attempt, platform), so ``resume`` can reconstruct exactly which attempts
were billed, which were in flight, and which materializations landed —
and never bill the same attempt twice.

``JournalState`` is the replayed view: billed attempts, launched-but-
unbilled frontier, landed materializations, money spent, and the adaptive
observations (BILL records double as ``OnlineCostModel`` training data, so
a resumed run carries forward everything the crashed run learned).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import warnings
from typing import IO, Any

TaskKey = tuple[str, str]  # (asset, partition)

#: record kinds a journal may contain (anything else fails validation)
KINDS = ("BEGIN", "LAUNCH", "BILL", "SUCCESS", "REPLAN", "RESUME",
         "FAIL", "END")


class JournalCorruption(UserWarning):
    """A journal line failed checksum/parse validation during replay."""


def _crc(body: str) -> str:
    return hashlib.sha1(body.encode()).hexdigest()[:8]


def _fsync_dir(path: str) -> None:
    """fsync a directory so a freshly created file survives power loss
    (no-op on platforms without O_RDONLY dir opens)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - non-posix
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class RunJournal:
    """Append-only, checksummed, fsync'd JSONL write-ahead log for one run.

    Each line is ``json.dumps(record, sort_keys=True)`` where
    ``record["crc"]`` is a checksum of the record *without* the crc field —
    a torn write (partial line, bit flip) is detected on replay instead of
    being parsed as garbage.  ``faults`` (a ``FaultPlan``) gets a
    ``journal_barrier`` callback after every durable append: the seeded
    chaos harness kills the coordinator at exact record boundaries, which
    is the worst case a real crash can produce (the record is durable, the
    action it describes may not have happened yet — or vice versa).
    """

    def __init__(self, directory: str, run_id: str, fsync: bool = True,
                 faults: "Any | None" = None):
        self.dir = directory
        self.run_id = run_id
        self.fsync = fsync
        self.faults = faults
        os.makedirs(directory, exist_ok=True)
        self.path = self.path_for(directory, run_id)
        existed = os.path.exists(self.path)
        self._f: IO[str] = open(self.path, "a")
        if not existed and fsync:
            _fsync_dir(directory)
        self._seq = self._count_existing()

    @staticmethod
    def path_for(directory: str, run_id: str) -> str:
        return os.path.join(directory, f"run-{run_id}.jsonl")

    def _count_existing(self) -> int:
        if self._f.tell() == 0:
            return 0
        records, _ = self.load(self.dir, self.run_id)
        return records[-1]["seq"] + 1 if records else 0

    # ------------------------------------------------------------------ write
    def append(self, kind: str, asset: str = "", partition: str = "",
               platform: str = "", attempt: int = 0, **payload: Any) -> dict:
        """Durably append one record and return it.  The fault barrier runs
        *after* the fsync: a chaos kill at record N leaves records 1..N on
        disk — exactly the state a power loss right after the write leaves.
        """
        if kind not in KINDS:
            raise ValueError(f"unknown journal record kind {kind!r}")
        rec = {"seq": self._seq, "ts": time.time(), "run": self.run_id,
               "kind": kind, "asset": asset, "partition": partition,
               "platform": platform, "attempt": attempt, "payload": payload}
        rec["crc"] = _crc(json.dumps(rec, sort_keys=True))
        self._f.write(json.dumps(rec, sort_keys=True) + "\n")
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self._seq += 1
        if self.faults is not None:
            self.faults.journal_barrier(self._seq)
        return rec

    def close(self) -> None:
        try:
            self._f.close()
        except OSError:  # pragma: no cover
            pass

    # ------------------------------------------------------------------- read
    @staticmethod
    def exists(directory: str, run_id: str) -> bool:
        return os.path.exists(RunJournal.path_for(directory, run_id))

    @staticmethod
    def load(directory: str, run_id: str) -> tuple[list[dict], int]:
        """Replay a journal file: returns (valid records, #dropped lines).

        Replay is torn-tail-tolerant: the first line that fails to parse or
        checksum ends the replay (everything after it is untrustworthy —
        with fsync'd appends that can only be a torn final write).  A
        mid-file corruption therefore also truncates the trusted prefix,
        which is the conservative reading: resume re-does work rather than
        trusting a record whose neighbours were mangled."""
        path = RunJournal.path_for(directory, run_id)
        records: list[dict] = []
        dropped = 0
        try:
            with open(path) as f:
                lines = f.readlines()
        except OSError:
            return [], 0
        for i, line in enumerate(lines):
            rec = RunJournal._validate_line(line)
            if rec is None or (records and rec["seq"] != records[-1]["seq"] + 1):
                dropped = len(lines) - i
                warnings.warn(
                    f"journal {os.path.basename(path)}: line {i + 1} failed "
                    f"validation; dropping it and the {dropped - 1} records "
                    f"after it (torn tail / corruption)", JournalCorruption,
                    stacklevel=2)
                break
            records.append(rec)
        return records, dropped

    @staticmethod
    def _validate_line(line: str) -> dict | None:
        line = line.strip()
        if not line:
            return None
        try:
            rec = json.loads(line)
        except (json.JSONDecodeError, UnicodeDecodeError):
            return None
        if not isinstance(rec, dict) or "crc" not in rec:
            return None
        crc = rec.pop("crc")
        if _crc(json.dumps(rec, sort_keys=True)) != crc:
            return None
        if rec.get("kind") not in KINDS or "seq" not in rec:
            return None
        rec["crc"] = crc
        return rec


@dataclasses.dataclass
class JournalState:
    """Replayed run state — what ``resume`` reconciles against the store.

    ``bills`` is ordered by seq and doubles as the adaptive warm-start
    stream (each BILL carries outcome / realized / predicted duration).
    """

    run_id: str
    targets: list[str] | None
    force: bool
    planned: bool
    use_cache: bool
    objective: dict[str, Any]
    launches: dict[TaskKey, list[dict]]
    bills: list[dict]
    bills_by_task: dict[TaskKey, list[dict]]
    succeeded: dict[TaskKey, dict]
    failed: set[TaskKey]
    replans: int
    resumes: int
    ended: bool
    ok: bool | None
    dropped_records: int
    last_seq: int

    @classmethod
    def from_records(cls, records: list[dict],
                     dropped: int = 0) -> "JournalState":
        if not records or records[0]["kind"] != "BEGIN":
            raise ValueError("journal has no BEGIN record (empty or torn "
                             "at birth) — nothing to resume")
        begin = records[0]["payload"]
        st = cls(run_id=records[0]["run"],
                 targets=begin.get("targets"),
                 force=bool(begin.get("force", False)),
                 planned=bool(begin.get("planned", False)),
                 use_cache=bool(begin.get("use_cache", True)),
                 objective=begin.get("objective", {}),
                 launches={}, bills=[], bills_by_task={}, succeeded={},
                 failed=set(), replans=0, resumes=0, ended=False, ok=None,
                 dropped_records=dropped, last_seq=records[-1]["seq"])
        for r in records:
            tk = (r["asset"], r["partition"])
            kind = r["kind"]
            if kind == "LAUNCH":
                st.launches.setdefault(tk, []).append(r)
            elif kind == "BILL":
                st.bills.append(r)
                st.bills_by_task.setdefault(tk, []).append(r)
            elif kind == "SUCCESS":
                st.succeeded[tk] = r
            elif kind == "FAIL":
                st.failed.add(tk)
            elif kind == "REPLAN":
                st.replans += 1
            elif kind == "RESUME":
                st.resumes += 1
                st.ended, st.ok = False, None  # the run is live again
            elif kind == "END":
                st.ended = True
                st.ok = bool(r["payload"].get("ok", False))
        return st

    # ------------------------------------------------------------- accounting
    @staticmethod
    def bill_key(rec: dict) -> tuple:
        """Idempotency key: one bill per (task, attempt, platform, twin?)."""
        return (rec["asset"], rec["partition"], rec["attempt"],
                rec["platform"], bool(rec["payload"].get("speculative")))

    def billed_keys(self) -> list[tuple]:
        return [self.bill_key(b) for b in self.bills]

    def spent_usd(self) -> float:
        return sum(b["payload"].get("cost_usd", 0.0) for b in self.bills)

    def terminal_attempts(self, tk: TaskKey) -> set[int]:
        """Attempt numbers with a non-speculative terminal bill."""
        return {b["attempt"] for b in self.bills_by_task.get(tk, [])
                if not b["payload"].get("speculative")}

    def in_flight(self) -> dict[TaskKey, list[dict]]:
        """Non-speculative LAUNCH records with no terminal bill for the same
        attempt — the attempts the crash cut down mid-air."""
        out: dict[TaskKey, list[dict]] = {}
        for tk, launches in self.launches.items():
            term = self.terminal_attempts(tk)
            orphans = [r for r in launches
                       if not r["payload"].get("speculative")
                       and r["attempt"] not in term]
            if orphans:
                out[tk] = orphans
        return out

    def frontier(self) -> set[TaskKey]:
        """Task keys whose work may need re-execution on resume: attempts
        in flight at the crash, plus success-billed attempts whose
        materialization never landed (crash between BILL and store put).
        Everything else is either durably done or durably failed-and-
        retryable exactly where the journal says."""
        out = set(self.in_flight())
        for tk, bills in self.bills_by_task.items():
            if tk in self.succeeded:
                continue
            # speculative counts too: a twin that won was success-billed
            # under the twin flag, and its put may equally have been lost
            if any(b["payload"].get("outcome") == "success" for b in bills):
                out.add(tk)
        return out

    def summary(self) -> str:
        lines = [f"run {self.run_id}: {len(self.succeeded)} landed, "
                 f"{len(self.bills)} bills (${self.spent_usd():.2f}), "
                 f"{len(self.frontier())} frontier task(s), "
                 f"replans={self.replans} resumes={self.resumes} "
                 f"ended={self.ended} ok={self.ok}"]
        if self.dropped_records:
            lines.append(f"  dropped {self.dropped_records} torn/corrupt "
                         f"journal record(s)")
        for tk, launches in sorted(self.in_flight().items()):
            atts = sorted(r["attempt"] for r in launches)
            lines.append(f"  in-flight {tk[0]}[{tk[1]}] attempt(s) {atts}")
        return "\n".join(lines)
