"""Cost & duration estimation — the decision core of the Dynamic Factory.

Duration comes from the three-term roofline (compute / memory / collective)
when the asset declares analytic work, or from calibrated chip-hours for
Table-1-style data assets; cost = duration x chips x (rate + surcharge)
+ storage, i.e. exactly the decomposition of the paper's Table 1
(Total = base + Platform Surcharge + EBS).
"""
from __future__ import annotations

import dataclasses

from repro.core.assets import AssetSpec, ComputeProfile
from repro.core.platforms import HBM_BW, ICI_BW, PEAK_FLOPS, Platform


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    platform: str
    duration_s: float  # wall-clock incl. startup
    compute_s: float
    base_usd: float
    surcharge_usd: float
    storage_usd: float
    feasible: bool = True
    reason: str = ""

    @property
    def total_usd(self) -> float:
        return self.base_usd + self.surcharge_usd + self.storage_usd


def roofline_seconds(c: ComputeProfile, chips: int) -> float:
    """max of the three roofline terms across the whole job."""
    if c.work_chip_hours > 0:
        return c.work_chip_hours * 3600.0 / max(1, chips)
    t_comp = c.flops / (chips * PEAK_FLOPS)
    t_mem = c.bytes_hbm / (chips * HBM_BW)
    t_coll = c.collective_bytes / (chips * ICI_BW)
    return max(t_comp, t_mem, t_coll, 1e-9)


class CostModel:
    """HBM-feasibility gate + roofline duration + Table-1 cost structure.

    Right-sizing: work-profiled assets (``work_chip_hours``) get a cluster
    sized to finish in ~``target_hours`` (the paper's "dynamic resource
    deployment with automatic scaling") — Table 1's small steps ran on small
    clusters (nodes: ~$0.40 at a rate that implies ~6 instances).  Analytic
    roofline assets (LM train/serve) always use the full mesh.
    """

    def __init__(self, hbm_gb_per_chip: float = 16.0,
                 target_hours: float = 0.9):
        self.hbm_gb = hbm_gb_per_chip
        self.target_hours = target_hours

    def chips_for(self, asset: AssetSpec, platform: Platform) -> int:
        c = asset.compute
        if c.work_chip_hours <= 0 or platform.kind == "local":
            return platform.chips
        perf = platform.perf_factor(c.speedup_class)
        want = int(c.work_chip_hours / (self.target_hours * perf)) + 1
        return max(c.min_chips, min(platform.chips, want))

    def estimate(self, asset: AssetSpec, platform: Platform) -> CostEstimate:
        c = asset.compute
        if platform.chips < c.min_chips:
            return CostEstimate(platform.name, float("inf"), float("inf"),
                                float("inf"), 0.0, 0.0, feasible=False,
                                reason=f"needs >= {c.min_chips} chips")
        if c.memory_gb_per_chip > self.hbm_gb and platform.kind != "local":
            return CostEstimate(platform.name, float("inf"), float("inf"),
                                float("inf"), 0.0, 0.0, feasible=False,
                                reason="exceeds HBM per chip")
        perf = platform.perf_factor(c.speedup_class)
        chips = self.chips_for(asset, platform)
        compute_s = roofline_seconds(c, chips) / max(perf, 1e-9)
        duration_s = compute_s + platform.startup_s
        hours = duration_s / 3600.0
        base = hours * chips * platform.chip_hour_usd
        surcharge = base * platform.surcharge_rate
        storage = hours * chips * platform.storage_usd_per_chip_hour
        return CostEstimate(platform.name, duration_s, compute_s, base,
                            surcharge, storage)

    def expected_cost_with_retries(self, est: CostEstimate,
                                   platform: Platform) -> float:
        """Failures burn money: E[cost] = cost / P(success) (geometric)."""
        p_ok = max(1e-3, 1.0 - platform.failure_rate - platform.preemption_rate)
        return est.total_usd / p_ok
