"""Cost & duration estimation — the decision core of the Dynamic Factory.

Duration comes from the three-term roofline (compute / memory / collective)
when the asset declares analytic work, or from calibrated chip-hours for
Table-1-style data assets; cost = duration x chips x (rate + surcharge)
+ storage, i.e. exactly the decomposition of the paper's Table 1
(Total = base + Platform Surcharge + EBS).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.assets import AssetSpec, ComputeProfile
from repro.core.platforms import HBM_BW, ICI_BW, PEAK_FLOPS, Platform


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    platform: str
    duration_s: float  # wall-clock incl. startup
    compute_s: float
    base_usd: float
    surcharge_usd: float
    storage_usd: float
    feasible: bool = True
    reason: str = ""

    @property
    def total_usd(self) -> float:
        return self.base_usd + self.surcharge_usd + self.storage_usd

    @staticmethod
    def cached() -> "CostEstimate":
        """The price of work already materialized in the store: ~0 cost and
        ~0 duration on the pseudo-platform ``"cached"`` — what the planner
        assigns to fresh (asset, partition) tasks so warm-cache plans
        collapse to the stale cone (see planner.py)."""
        return CostEstimate(platform="cached", duration_s=0.0, compute_s=0.0,
                            base_usd=0.0, surcharge_usd=0.0, storage_usd=0.0)


def roofline_seconds(c: ComputeProfile, chips: int) -> float:
    """max of the three roofline terms across the whole job."""
    if c.work_chip_hours > 0:
        return c.work_chip_hours * 3600.0 / max(1, chips)
    t_comp = c.flops / (chips * PEAK_FLOPS)
    t_mem = c.bytes_hbm / (chips * HBM_BW)
    t_coll = c.collective_bytes / (chips * ICI_BW)
    return max(t_comp, t_mem, t_coll, 1e-9)


class CostModel:
    """HBM-feasibility gate + roofline duration + Table-1 cost structure.

    Right-sizing: work-profiled assets (``work_chip_hours``) get a cluster
    sized to finish in ~``target_hours`` (the paper's "dynamic resource
    deployment with automatic scaling") — Table 1's small steps ran on small
    clusters (nodes: ~$0.40 at a rate that implies ~6 instances).  Analytic
    roofline assets (LM train/serve) always use the full mesh.
    """

    def __init__(self, hbm_gb_per_chip: float = 16.0,
                 target_hours: float = 0.9,
                 rework_fraction: float = 0.5):
        self.hbm_gb = hbm_gb_per_chip
        self.target_hours = target_hours
        #: expected fraction of an attempt's duration lost when it fails or
        #: is preempted mid-run (the simulated clients inject uniform(0.2,
        #: 0.8) partial progress, mean 0.5) — drives ``schedule_duration``.
        self.rework_fraction = rework_fraction

    def _p_ok(self, platform: Platform, asset: str | None = None) -> float:
        """Single-attempt success probability used for retry/rework math.

        The base model only knows catalog beliefs; ``OnlineCostModel``
        overrides this with per-(asset, platform) observed rates.  Every
        consumer (scalar and batched) must go through this hook so the two
        paths price identically.
        """
        return platform.p_success()

    def chips_for(self, asset: AssetSpec, platform: Platform) -> int:
        c = asset.compute
        if c.work_chip_hours <= 0 or platform.kind == "local":
            return platform.chips
        perf = platform.perf_factor(c.speedup_class)
        want = int(c.work_chip_hours / (self.target_hours * perf)) + 1
        return max(c.min_chips, min(platform.chips, want))

    def estimate(self, asset: AssetSpec, platform: Platform) -> CostEstimate:
        c = asset.compute
        if platform.chips < c.min_chips:
            return CostEstimate(platform.name, float("inf"), float("inf"),
                                float("inf"), 0.0, 0.0, feasible=False,
                                reason=f"needs >= {c.min_chips} chips")
        if c.memory_gb_per_chip > self.hbm_gb and platform.kind != "local":
            return CostEstimate(platform.name, float("inf"), float("inf"),
                                float("inf"), 0.0, 0.0, feasible=False,
                                reason="exceeds HBM per chip")
        perf = platform.perf_factor(c.speedup_class)
        chips = self.chips_for(asset, platform)
        compute_s = roofline_seconds(c, chips) / max(perf, 1e-9)
        duration_s = compute_s + platform.startup_s
        hours = duration_s / 3600.0
        base = hours * chips * platform.chip_hour_usd
        surcharge = base * platform.surcharge_rate
        storage = hours * chips * platform.storage_usd_per_chip_hour
        return CostEstimate(platform.name, duration_s, compute_s, base,
                            surcharge, storage)

    def expected_cost_with_retries(self, est: CostEstimate,
                                   platform: Platform,
                                   asset: str | None = None) -> float:
        """Failures burn money: E[cost] = cost / P(success) (geometric)."""
        return est.total_usd / self._p_ok(platform, asset)

    def schedule_duration(self, est: CostEstimate, platform: Platform,
                          asset: str | None = None) -> float:
        """Expected *wall-clock* duration including rework after failures
        and preemptions: the geometric retry count E[attempts] = 1/p adds
        (1/p - 1) failed attempts, each burning ``rework_fraction`` of the
        nominal duration before dying.  This is what preemption-aware
        scheduling loads onto the timeline (cost already has its own
        ``expected_cost_with_retries`` term)."""
        if not est.feasible:
            return float("inf")
        p_ok = self._p_ok(platform, asset)
        return est.duration_s * (
            1.0 + self.rework_fraction * (1.0 - p_ok) / p_ok)

    # ------------------------------------------------------ subclass hooks
    def _p_ok_col(self, platform: Platform,
                  specs: Sequence[AssetSpec]) -> np.ndarray:
        """Per-asset success probabilities on ``platform`` as a column.
        Must produce exactly the floats ``_p_ok`` returns per asset."""
        return np.full(len(specs), self._p_ok(platform))

    def _dur_ratio_col(self, platform: Platform,
                       specs: Sequence[AssetSpec]) -> np.ndarray | None:
        """Per-asset realized/predicted duration ratios (``None`` = no
        scaling).  The static model has no observations; ``OnlineCostModel``
        returns its EWMA ratios here so batched pricing sees the same
        corrections as scalar ``estimate``."""
        return None

    # ------------------------------------------------------------ batched
    def estimate_batch(self, specs: Sequence[AssetSpec],
                       platforms: Sequence[Platform]) -> dict[str, np.ndarray]:
        """Vectorized ``estimate`` + ``expected_cost_with_retries`` over all
        assets x platforms in one numpy pass.

        Returns ``[n_assets, n_platforms]`` arrays: ``duration_s``,
        ``total_usd``, ``expected_usd`` (retry-aware), ``sched_duration_s``
        (rework-aware wall clock, see ``schedule_duration``), the
        ``CostEstimate`` components (``compute_s``, ``base_usd``,
        ``surcharge_usd``, ``storage_usd``) and a boolean ``feasible`` mask
        (infeasible cells carry +inf duration/cost, zero surcharge/storage —
        same as the scalar path).  The arithmetic mirrors the scalar path op-for-op so
        batch and scalar pricing agree bit-for-bit — the planner prices
        10k-task DAGs through this instead of a per-task Python loop, and
        re-assembles per-choice ``CostEstimate`` objects from these columns
        without ever calling scalar ``estimate``.
        """
        n, m = len(specs), len(platforms)
        work = np.array([s.compute.work_chip_hours for s in specs], dtype=np.float64)
        flops = np.array([s.compute.flops for s in specs], dtype=np.float64)
        bytes_hbm = np.array([s.compute.bytes_hbm for s in specs], dtype=np.float64)
        coll = np.array([s.compute.collective_bytes for s in specs], dtype=np.float64)
        min_chips = np.array([s.compute.min_chips for s in specs], dtype=np.int64)
        mem = np.array([s.compute.memory_gb_per_chip for s in specs], dtype=np.float64)
        classes = [s.compute.speedup_class for s in specs]
        uniq = sorted(set(classes))
        cls_idx = np.array([uniq.index(c) for c in classes], dtype=np.int64)

        shape = (n, m)
        duration = np.full(shape, np.inf)
        total = np.full(shape, np.inf)
        expected = np.full(shape, np.inf)
        sched_duration = np.full(shape, np.inf)
        compute = np.full(shape, np.inf)
        base_usd = np.full(shape, np.inf)
        surcharge_usd = np.zeros(shape)
        storage_usd = np.zeros(shape)
        feasible = np.zeros(shape, dtype=bool)
        out = {"duration_s": duration, "total_usd": total,
               "expected_usd": expected, "sched_duration_s": sched_duration,
               "compute_s": compute,
               "base_usd": base_usd, "surcharge_usd": surcharge_usd,
               "storage_usd": storage_usd, "feasible": feasible}
        if n == 0:
            return out

        has_work = work > 0
        for j, p in enumerate(platforms):
            ok = (p.chips >= min_chips) & (
                (mem <= self.hbm_gb) | (p.kind == "local"))
            perf = np.array([p.perf_factor(c) for c in uniq])[cls_idx]
            # chips_for: right-size work-profiled assets, full mesh otherwise
            with np.errstate(divide="ignore", invalid="ignore"):
                want = np.where(
                    has_work,
                    (work / (self.target_hours * perf)), 0.0)
            want = want.astype(np.int64) + 1
            chips = np.where(
                has_work & (p.kind != "local"),
                np.maximum(min_chips, np.minimum(p.chips, want)),
                p.chips)
            chips_f = chips.astype(np.float64)
            # roofline_seconds
            t_work = work * 3600.0 / np.maximum(1, chips_f)
            t_analytic = np.maximum.reduce([
                flops / (chips_f * PEAK_FLOPS),
                bytes_hbm / (chips_f * HBM_BW),
                coll / (chips_f * ICI_BW),
                np.full(n, 1e-9)])
            roof = np.where(has_work, t_work, t_analytic)
            compute_s = roof / np.maximum(perf, 1e-9)
            dur = compute_s + p.startup_s
            hours = dur / 3600.0
            base = hours * chips_f * p.chip_hour_usd
            surch = base * p.surcharge_rate
            stor = hours * chips_f * p.storage_usd_per_chip_hour
            ratio = self._dur_ratio_col(p, specs)
            if ratio is not None:
                # Mirror the scalar OnlineCostModel path: scale each
                # component, then re-sum — NOT tot * ratio, which rounds
                # differently and would break scalar/batch bit-identity.
                dur = dur * ratio
                compute_s = compute_s * ratio
                base = base * ratio
                surch = surch * ratio
                stor = stor * ratio
            tot = base + surch + stor
            pok = self._p_ok_col(p, specs)
            sched = dur * (
                1.0 + self.rework_fraction * (1.0 - pok) / pok)
            duration[:, j] = np.where(ok, dur, np.inf)
            total[:, j] = np.where(ok, tot, np.inf)
            expected[:, j] = np.where(ok, tot / pok, np.inf)
            sched_duration[:, j] = np.where(ok, sched, np.inf)
            compute[:, j] = np.where(ok, compute_s, np.inf)
            base_usd[:, j] = np.where(ok, base, np.inf)
            surcharge_usd[:, j] = np.where(ok, surch, 0.0)
            storage_usd[:, j] = np.where(ok, stor, 0.0)
            feasible[:, j] = ok
        return out
