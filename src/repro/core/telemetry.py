"""Message Reader: structured telemetry capture (paper component #2).

Every run emits events (submit / start / heartbeat / materialize / finish /
fail / cancel / cost-report / scaling).  The reader aggregates them for the
monitoring benchmarks (Fig 3 run-state counts, Fig 6 duration distributions)
and powers straggler detection plus the closed-loop adaptive controller in
the coordinator.

Long-lived fleet/serving runs can bound memory with ``max_events``: when the
live list reaches the cap, the oldest half is folded into compacted
aggregates (outcome counts, cost totals, duration summaries, cache stats)
before eviction, so the Fig-3/Table-1 rollups keep reporting lifetime
numbers while ``events()`` only returns the live window.
"""
from __future__ import annotations

import dataclasses
import statistics
import threading
import time
from typing import Any, Iterable

#: Terminal-outcome buckets reported by ``outcome_counts`` — preemptions are
#: their own bucket (the clients tag ``FAILURE`` events with
#: ``failure_kind``), not lumped into ``failure``.
OUTCOME_KEYS = ("success", "failure", "preemption", "cancelled")


@dataclasses.dataclass(frozen=True)
class Event:
    ts: float
    run_id: str
    asset: str
    partition: str
    platform: str
    kind: str  # SUBMIT|START|HEARTBEAT|MATERIALIZE|SUCCESS|FAILURE|CANCEL|COST|SCALING|RETRY|FAILOVER|SPECULATE|CACHE_HIT|STALE|REPLAN|BREAKER
    payload: dict[str, Any] = dataclasses.field(default_factory=dict)
    seq: int = 0  # monotonically increasing per reader; survives compaction

    def outcome_key(self) -> str | None:
        """The ``outcome_counts`` bucket this event lands in, if any."""
        if self.kind == "SUCCESS":
            return "success"
        if self.kind == "CANCEL":
            return "cancelled"
        if self.kind == "FAILURE":
            if self.payload.get("failure_kind") == "preemption":
                return "preemption"
            return "failure"
        return None


class MessageReader:
    def __init__(self, max_events: int | None = None) -> None:
        if max_events is not None and max_events < 2:
            raise ValueError("max_events must be >= 2 (or None for unbounded)")
        self._events: list[Event] = []
        self._lock = threading.Lock()
        self._next_seq = 0
        self._max_events = max_events
        self._evicted = 0
        # Compacted aggregates — folded in before eviction so the rollups
        # below keep lifetime totals.
        self._c_outcomes: dict[str, dict[str, int]] = {}
        self._c_cost_by_platform: dict[str, float] = {}
        self._c_cost_by_asset: dict[str, float] = {}
        self._c_dur: dict[str, list[float]] = {}  # asset -> [n, sum]
        self._c_cache: dict[str, dict[str, Any]] = {}  # run_id -> stats

    def emit(self, run_id: str, asset: str, partition: str, platform: str,
             kind: str, **payload: Any) -> Event:
        with self._lock:
            ev = Event(time.time(), run_id, asset, partition, platform, kind,
                       dict(payload), seq=self._next_seq)
            self._next_seq += 1
            self._events.append(ev)
            if (self._max_events is not None
                    and len(self._events) > self._max_events):
                self._compact_locked()
        return ev

    # ------------------------------------------------------------ compaction
    def _compact_locked(self) -> None:
        """Fold the oldest half of the live window into the aggregate
        summaries and drop it.  Called with the lock held."""
        keep_from = max(1, len(self._events) // 2)
        evicted, self._events = (self._events[:keep_from],
                                 self._events[keep_from:])
        self._evicted += len(evicted)
        for e in evicted:
            self._fold(e)

    def _fold(self, e: Event) -> None:
        key = e.outcome_key()
        if key is not None:
            d = self._c_outcomes.setdefault(
                e.platform, {k: 0 for k in OUTCOME_KEYS})
            d[key] += 1
        if e.kind == "SUCCESS" and "duration_s" in e.payload:
            agg = self._c_dur.setdefault(e.asset, [0, 0.0])
            agg[0] += 1
            agg[1] += e.payload["duration_s"]
        if e.kind == "COST":
            usd = e.payload.get("total_usd", 0.0)
            self._c_cost_by_platform[e.platform] = (
                self._c_cost_by_platform.get(e.platform, 0.0) + usd)
            self._c_cost_by_asset[e.asset] = (
                self._c_cost_by_asset.get(e.asset, 0.0) + usd)
        if e.kind in ("CACHE_HIT", "STALE") or (
                e.kind == "SUCCESS" and not e.payload.get("cached")):
            cs = self._c_cache.setdefault(
                e.run_id, {"cache_hits": 0, "executed": 0,
                           "stale_reasons": {}})
            if e.kind == "CACHE_HIT":
                cs["cache_hits"] += 1
            elif e.kind == "STALE":
                reason = e.payload.get("reason", "unknown").split(":")[0]
                cs["stale_reasons"][reason] = (
                    cs["stale_reasons"].get(reason, 0) + 1)
            else:
                cs["executed"] += 1

    @property
    def evicted_events(self) -> int:
        """How many events compaction has folded away (0 when unbounded)."""
        with self._lock:
            return self._evicted

    @property
    def min_live_seq(self) -> int:
        """Smallest seq still in the live window (= next seq when empty).
        A consumer whose cursor is older than this has lost events to
        compaction — ``events_since`` cannot return them."""
        with self._lock:
            return self._events[0].seq if self._events else self._next_seq

    # ------------------------------------------------------------ access
    def events(self, kind: str | None = None, asset: str | None = None,
               platform: str | None = None) -> list[Event]:
        """The live (non-compacted) event window, optionally filtered."""
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        if asset is not None:
            evs = [e for e in evs if e.asset == asset]
        if platform is not None:
            evs = [e for e in evs if e.platform == platform]
        return evs

    def events_since(self, seq: int) -> list[Event]:
        """Live events with ``e.seq >= seq`` — the adaptive controller's
        incremental cursor (keep ``last.seq + 1`` between calls).  Events
        evicted by compaction are gone; callers that must not miss events
        should size ``max_events`` above their polling interval's volume."""
        with self._lock:
            return [e for e in self._events if e.seq >= seq]

    # ------------------------------------------------------------ aggregates
    def outcome_counts(self) -> dict[str, dict[str, int]]:
        """platform -> {success, failure, preemption, cancelled} — Fig 3.

        ``FAILURE`` events tagged ``failure_kind == "preemption"`` land in
        the ``preemption`` bucket; ``failure`` counts hard failures only.
        All four keys are always present per platform.
        """
        with self._lock:
            out: dict[str, dict[str, int]] = {
                p: dict(d) for p, d in self._c_outcomes.items()}
        for e in self.events():
            key = e.outcome_key()
            if key is not None:
                d = out.setdefault(e.platform, {k: 0 for k in OUTCOME_KEYS})
                d[key] += 1
        return out

    def durations(self, asset: str | None = None,
                  platform: str | None = None) -> list[float]:
        """Realized durations from the live window (compacted events only
        survive as the per-asset mean — see ``median_duration``)."""
        return [e.payload["duration_s"]
                for e in self.events(kind="SUCCESS", asset=asset,
                                     platform=platform)
                if "duration_s" in e.payload]

    def median_duration(self, asset: str) -> float | None:
        d = self.durations(asset=asset)
        if d:
            return statistics.median(d)
        with self._lock:
            agg = list(self._c_dur.get(asset, ()))
        if agg and agg[0] > 0:
            return agg[1] / agg[0]  # compacted fallback: lifetime mean
        return None

    def total_cost(self, platform: str | None = None) -> float:
        with self._lock:
            if platform is None:
                compacted = sum(self._c_cost_by_platform.values())
            else:
                compacted = self._c_cost_by_platform.get(platform, 0.0)
        return compacted + sum(e.payload.get("total_usd", 0.0)
                               for e in self.events(kind="COST",
                                                    platform=platform))

    def cost_by_asset(self) -> dict[str, float]:
        with self._lock:
            out: dict[str, float] = dict(self._c_cost_by_asset)
        for e in self.events(kind="COST"):
            out[e.asset] = out.get(e.asset, 0.0) + e.payload.get("total_usd", 0.0)
        return out

    def cache_stats(self, run_id: str | None = None) -> dict[str, Any]:
        """Incremental-materialization aggregate: cache hits, executions and
        the per-reason staleness breakdown (``STALE`` events are emitted by
        the coordinator's upfront resolution, ``CACHE_HIT`` at launch time —
        a task can be pessimistically stale yet still hit via early cutoff).
        """
        hits = executed = 0
        reasons: dict[str, int] = {}
        with self._lock:
            compacted_cache = {rid: {"cache_hits": cs["cache_hits"],
                                     "executed": cs["executed"],
                                     "stale_reasons": dict(cs["stale_reasons"])}
                               for rid, cs in self._c_cache.items()}
        for rid, cs in compacted_cache.items():
            if run_id is not None and rid != run_id:
                continue
            hits += cs["cache_hits"]
            executed += cs["executed"]
            for reason, cnt in cs["stale_reasons"].items():
                reasons[reason] = reasons.get(reason, 0) + cnt
        for e in self.events():
            if run_id is not None and e.run_id != run_id:
                continue
            if e.kind == "CACHE_HIT":
                hits += 1
            elif e.kind == "SUCCESS" and not e.payload.get("cached"):
                executed += 1
            elif e.kind == "STALE":
                reason = e.payload.get("reason", "unknown").split(":")[0]
                reasons[reason] = reasons.get(reason, 0) + 1
        return {"cache_hits": hits, "executed": executed,
                "stale_reasons": reasons,
                "hit_rate": hits / max(1, hits + executed)}

    def tail(self, n: int = 20) -> Iterable[Event]:
        with self._lock:
            return list(self._events[-n:])
