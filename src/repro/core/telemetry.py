"""Message Reader: structured telemetry capture (paper component #2).

Every run emits events (submit / start / heartbeat / materialize / finish /
fail / cancel / cost-report / scaling).  The reader aggregates them for the
monitoring benchmarks (Fig 3 run-state counts, Fig 6 duration distributions)
and powers straggler detection in the coordinator.
"""
from __future__ import annotations

import dataclasses
import statistics
import threading
import time
from typing import Any, Iterable


@dataclasses.dataclass(frozen=True)
class Event:
    ts: float
    run_id: str
    asset: str
    partition: str
    platform: str
    kind: str  # SUBMIT|START|HEARTBEAT|MATERIALIZE|SUCCESS|FAILURE|CANCEL|COST|SCALING|RETRY|FAILOVER|SPECULATE|CACHE_HIT|STALE
    payload: dict[str, Any] = dataclasses.field(default_factory=dict)


class MessageReader:
    def __init__(self) -> None:
        self._events: list[Event] = []
        self._lock = threading.Lock()

    def emit(self, run_id: str, asset: str, partition: str, platform: str,
             kind: str, **payload: Any) -> Event:
        ev = Event(time.time(), run_id, asset, partition, platform, kind,
                   dict(payload))
        with self._lock:
            self._events.append(ev)
        return ev

    def events(self, kind: str | None = None, asset: str | None = None,
               platform: str | None = None) -> list[Event]:
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        if asset is not None:
            evs = [e for e in evs if e.asset == asset]
        if platform is not None:
            evs = [e for e in evs if e.platform == platform]
        return evs

    # ------------------------------------------------------------ aggregates
    def outcome_counts(self) -> dict[str, dict[str, int]]:
        """platform -> {success, failure, cancelled} — Fig 3."""
        out: dict[str, dict[str, int]] = {}
        for e in self.events():
            if e.kind in ("SUCCESS", "FAILURE", "CANCEL"):
                d = out.setdefault(e.platform, {"success": 0, "failure": 0,
                                                "cancelled": 0})
                key = {"SUCCESS": "success", "FAILURE": "failure",
                       "CANCEL": "cancelled"}[e.kind]
                d[key] += 1
        return out

    def durations(self, asset: str | None = None,
                  platform: str | None = None) -> list[float]:
        return [e.payload["duration_s"]
                for e in self.events(kind="SUCCESS", asset=asset,
                                     platform=platform)
                if "duration_s" in e.payload]

    def median_duration(self, asset: str) -> float | None:
        d = self.durations(asset=asset)
        return statistics.median(d) if d else None

    def total_cost(self, platform: str | None = None) -> float:
        return sum(e.payload.get("total_usd", 0.0)
                   for e in self.events(kind="COST", platform=platform))

    def cost_by_asset(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for e in self.events(kind="COST"):
            out[e.asset] = out.get(e.asset, 0.0) + e.payload.get("total_usd", 0.0)
        return out

    def cache_stats(self, run_id: str | None = None) -> dict[str, Any]:
        """Incremental-materialization aggregate: cache hits, executions and
        the per-reason staleness breakdown (``STALE`` events are emitted by
        the coordinator's upfront resolution, ``CACHE_HIT`` at launch time —
        a task can be pessimistically stale yet still hit via early cutoff).
        """
        hits = executed = 0
        reasons: dict[str, int] = {}
        for e in self.events():
            if run_id is not None and e.run_id != run_id:
                continue
            if e.kind == "CACHE_HIT":
                hits += 1
            elif e.kind == "SUCCESS" and not e.payload.get("cached"):
                executed += 1
            elif e.kind == "STALE":
                reason = e.payload.get("reason", "unknown").split(":")[0]
                reasons[reason] = reasons.get(reason, 0) + 1
        return {"cache_hits": hits, "executed": executed,
                "stale_reasons": reasons,
                "hit_rate": hits / max(1, hits + executed)}

    def tail(self, n: int = 20) -> Iterable[Event]:
        with self._lock:
            return list(self._events[-n:])
