"""Execution-platform catalog.

The paper's platforms (local / EMR / Databricks) become TPU execution
environments with the same *economic* structure: a base chip-hour rate, a
platform surcharge (the DBU analogue), a runtime performance factor (the
Photon analogue), a startup latency, and a reliability profile (EMR's higher
failure rate, Fig 3).  Constants are calibrated to Table 1 — see
DESIGN.md §7 and benchmarks/table1_cost.py.

v5e hardware constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI — shared with the roofline analysis.
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

#: Photon-analogue: how much the premium runtime accelerates each workload
#: class (calibrated: Table 1 edges ~1.5x, graph/shuffle ~2.4x, light ~1.2x).
SPEEDUP_CLASSES = {
    "scan": {"premium": 1.5},
    "shuffle": {"premium": 2.4},
    "light": {"premium": 1.2},
    "train": {"premium": 1.25},
    "serve": {"premium": 1.2},
}


@dataclasses.dataclass(frozen=True)
class Platform:
    name: str
    kind: str  # local | spot | premium | multipod
    chips: int
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    chip_hour_usd: float
    surcharge_rate: float  # fraction of base compute cost (DBU analogue)
    perf_class: str = ""  # key into SPEEDUP_CLASSES factors ("" => 1.0)
    startup_s: float = 0.0
    failure_rate: float = 0.0  # P(run-level failure) per attempt
    preemption_rate: float = 0.0  # P(preemption mid-run) per attempt
    storage_usd_per_chip_hour: float = 0.0  # EBS-analogue
    perf_factor_base: float = 1.0  # generic runtime speed multiplier

    def perf_factor(self, speedup_class: str) -> float:
        extra = SPEEDUP_CLASSES.get(speedup_class, {}).get(self.kind, 1.0) \
            if self.kind == "premium" else 1.0
        return self.perf_factor_base * extra

    def effective_rate(self) -> float:
        """USD per chip-hour including surcharge."""
        return self.chip_hour_usd * (1.0 + self.surcharge_rate)

    def p_success(self) -> float:
        """Catalog belief of a single attempt succeeding (floored so the
        geometric retry expectation stays finite) — the one expression every
        retry/rework computation must share so scalar and batched pricing
        agree bit-for-bit."""
        return max(1e-3, 1.0 - self.failure_rate - self.preemption_rate)


def default_catalog() -> dict[str, Platform]:
    """Calibrated to Table 1 economics (spot ~ EMR, premium ~ DBR)."""
    base = 0.145  # spot-ish v5e $/chip-hour (relative scale is what matters)
    return {
        "local": Platform(
            name="local", kind="local", chips=1, mesh_shape=(1,),
            mesh_axes=("data",), chip_hour_usd=0.0, surcharge_rate=0.0,
            perf_factor_base=0.02,  # debug-scale hardware
        ),
        "pod-spot": Platform(
            name="pod-spot", kind="spot", chips=256, mesh_shape=(16, 16),
            mesh_axes=("data", "model"), chip_hour_usd=base,
            surcharge_rate=0.26,  # EMR service-fee ratio from Table 1
            startup_s=300.0, failure_rate=0.22, preemption_rate=0.08,
            storage_usd_per_chip_hour=0.006,  # EBS: edges $13.7 @ 8.6h*256
        ),
        "pod-premium": Platform(
            name="pod-premium", kind="premium", chips=256, mesh_shape=(16, 16),
            mesh_axes=("data", "model"), chip_hour_usd=base * 2.4,
            surcharge_rate=0.48,  # DBU ratio from Table 1
            perf_class="scan", startup_s=120.0, failure_rate=0.10,
            preemption_rate=0.02, storage_usd_per_chip_hour=0.012,
        ),
        "multipod-spot": Platform(
            name="multipod-spot", kind="spot", chips=512,
            mesh_shape=(2, 16, 16), mesh_axes=("pod", "data", "model"),
            chip_hour_usd=base, surcharge_rate=0.26, startup_s=420.0,
            failure_rate=0.28, preemption_rate=0.10,
            storage_usd_per_chip_hour=0.006,
        ),
    }
