"""Generic platform clients (paper components #3/#4): one protocol, multiple
execution environments.

``LocalClient`` executes in-process (the rapid-prototyping path the paper
emphasizes).  ``SimulatedClusterClient`` *really executes* the asset function
(everything in this container runs on local devices) while modelling the
platform's economics and reliability: simulated wall-clock from the cost
model, per-attempt failure/preemption injection with a deterministic RNG —
this is what makes the Fig-3 reliability study reproducible.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import uuid
from typing import Any, Callable

import numpy as np

from repro.core.costmodel import CostEstimate
from repro.core.context import RunContext
from repro.core.platforms import Platform


class PlatformError(RuntimeError):
    def __init__(self, msg: str, kind: str = "failure"):
        super().__init__(msg)
        self.kind = kind  # failure | preemption


@dataclasses.dataclass
class JobSpec:
    fn: Callable[..., Any]
    args: tuple
    kwargs: dict
    ctx: RunContext
    estimate: CostEstimate


@dataclasses.dataclass
class RunHandle:
    job_id: str
    platform: str
    thread: threading.Thread | None = None
    result: Any = None
    error: Exception | None = None
    cancelled: bool = False
    started: float = 0.0
    finished: float = 0.0
    sim_duration_s: float = 0.0

    def done(self) -> bool:
        return self.thread is None or not self.thread.is_alive()


class PlatformClient:
    """Protocol: submit / poll / cancel / logs."""

    platform: Platform

    def submit(self, job: JobSpec) -> RunHandle:
        raise NotImplementedError

    def poll(self, h: RunHandle, timeout: float | None = None) -> RunHandle:
        if h.thread is not None:
            h.thread.join(timeout)
        return h

    def cancel(self, h: RunHandle) -> None:
        h.cancelled = True

    def logs(self, h: RunHandle) -> str:
        state = ("cancelled" if h.cancelled else
                 "error" if h.error else
                 "done" if h.done() else "running")
        return f"[{self.platform.name}] job {h.job_id}: {state}"


class LocalClient(PlatformClient):
    def __init__(self, platform: Platform):
        self.platform = platform

    def submit(self, job: JobSpec) -> RunHandle:
        h = RunHandle(job_id=uuid.uuid4().hex[:12], platform=self.platform.name)

        def run():
            h.started = time.time()
            try:
                h.result = job.fn(job.ctx, *job.args, **job.kwargs)
            except Exception as e:  # surfaced via poll
                h.error = e
            h.finished = time.time()
            h.sim_duration_s = h.finished - h.started

        h.thread = threading.Thread(target=run, daemon=True)
        h.thread.start()
        return h


class SimulatedClusterClient(PlatformClient):
    """Real execution + simulated platform economics and reliability.

    Fault injection is deterministic in (run_id, asset, partition, attempt),
    so reliability experiments replay exactly.
    """

    def __init__(self, platform: Platform, seed: int = 0,
                 sim_time_scale: float = 0.0,
                 failure_rate: float | None = None,
                 preemption_rate: float | None = None,
                 duration_bias: float = 1.0):
        self.platform = platform
        self.seed = seed
        #: 0.0 => don't sleep at all (pure accounting); >0 => sleep
        #: sim_duration * scale to exercise real concurrency/stragglers.
        self.sim_time_scale = sim_time_scale
        #: *actual* reliability may diverge from the catalog's belief —
        #: that gap is what retries/failover/speculation exist for.
        self.failure_rate = (platform.failure_rate if failure_rate is None
                             else failure_rate)
        self.preemption_rate = (platform.preemption_rate
                                if preemption_rate is None else preemption_rate)
        #: straggling: > 1; may be a callable RunContext -> float so tests
        #: and chaos experiments can straggle specific partitions.
        self.duration_bias = duration_bias

    def _rng(self, ctx: RunContext) -> np.random.RandomState:
        import hashlib

        key = (self.seed, ctx.run_id, ctx.asset, ctx.partition_key,
               ctx.attempt, self.platform.name)
        digest = hashlib.sha1(repr(key).encode()).digest()
        return np.random.RandomState(
            int.from_bytes(digest[:4], "little") % (2**31))

    def submit(self, job: JobSpec) -> RunHandle:
        h = RunHandle(job_id=uuid.uuid4().hex[:12], platform=self.platform.name)
        rng = self._rng(job.ctx)

        bias = (self.duration_bias(job.ctx) if callable(self.duration_bias)
                else self.duration_bias)

        def run():
            h.started = time.time()
            p = self.platform
            # simulated wall-clock: roofline estimate with log-normal jitter
            jitter = float(np.exp(rng.normal(0.0, 0.18))) * bias
            sim = job.estimate.duration_s * jitter
            draw = rng.uniform()
            try:
                failed = draw < self.failure_rate
                preempted = (not failed and
                             draw < self.failure_rate + self.preemption_rate)
                # partial progress before dying: drawn last so the
                # jitter/outcome stream is unchanged vs earlier seeds
                bad = failed or preempted
                frac = float(rng.uniform(0.2, 0.8)) if bad else 1.0
                if self.sim_time_scale > 0:
                    deadline = time.time() + sim * self.sim_time_scale * frac
                    while time.time() < deadline:
                        if h.cancelled:
                            h.finished = time.time()
                            return
                        time.sleep(min(0.002, deadline - time.time()))
                if failed:
                    raise PlatformError(
                        f"{p.name}: injected run failure (draw={draw:.3f})",
                        kind="failure")
                if preempted:
                    raise PlatformError(
                        f"{p.name}: injected preemption", kind="preemption")
                h.result = job.fn(job.ctx, *job.args, **job.kwargs)
                h.sim_duration_s = sim
            except Exception as e:
                h.error = e
                # failed/preempted attempts bill the partial progress they
                # actually burned (the drawn 0.2-0.8 fraction), not a flat
                # half — keeps billed cost consistent with simulated time
                h.sim_duration_s = sim * (frac if isinstance(e, PlatformError)
                                          else 1.0)
            h.finished = time.time()

        h.thread = threading.Thread(target=run, daemon=True)
        h.thread.start()
        return h
