"""Unified asset selection — one expression surface for planner,
coordinator and CLI.

``RunCoordinator.plan()`` / ``materialize()`` and ``launch/dryrun.py`` used
to accept a stringly-typed ``targets: list[str] | None`` with slightly
different behavior at each call site.  ``AssetSelection`` replaces that with
a small composable expression type resolved against an ``AssetGraph``:

    AssetSelection.assets("edges")                  # explicit names
    AssetSelection.assets("nodes").downstream()     # nodes + its consumers
    AssetSelection.tag("team", "crawl")             # tag filter
    AssetSelection.group("ingest") | AssetSelection.assets("report")
    (sel_a & sel_b) - AssetSelection.assets("scratch")

``parse`` accepts the CLI syntax used by ``dryrun --select``:

    "edges"           that asset
    "nodes+"          the asset and its downstream closure (backfill cone)
    "+graph"          the asset and its upstream closure
    "+graph+"         both closures
    "tag:team=crawl"  tag filter (value optional: "tag:team")
    "group:ingest"    group filter (sugar for tag:group=<name>)
    "*"               everything
    "a,b+,tag:x=y"    comma/whitespace-separated clauses union

``coerce`` keeps every legacy call site working: ``None`` selects all,
``list[str]`` selects those names, a string goes through ``parse``, and an
``AssetSelection`` passes through — so planner, coordinator and CLI agree
on one selection surface.
"""
from __future__ import annotations

import dataclasses
import re
from typing import TYPE_CHECKING, Iterable

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.assets import AssetGraph

_CLAUSE = re.compile(r"^(?P<up>\+?)(?P<body>[^+\s]+)(?P<down>\+?)$")


class AssetSelection:
    """Composable selection expression; build via the static factories and
    combine with ``|`` (union), ``&`` (intersection), ``-`` (difference)."""

    # ------------------------------------------------------------ factories
    @staticmethod
    def all() -> "AssetSelection":
        return _All()

    @staticmethod
    def assets(*names: str) -> "AssetSelection":
        return _Keys(tuple(names))

    @staticmethod
    def tag(key: str, value: str | None = None) -> "AssetSelection":
        return _Tag(key, value)

    @staticmethod
    def group(name: str) -> "AssetSelection":
        """Sugar for the conventional ``group`` tag."""
        return _Tag("group", name)

    # ---------------------------------------------------------- combinators
    def __or__(self, other: "AssetSelection") -> "AssetSelection":
        return _Binary("|", self, other)

    def __and__(self, other: "AssetSelection") -> "AssetSelection":
        return _Binary("&", self, other)

    def __sub__(self, other: "AssetSelection") -> "AssetSelection":
        return _Binary("-", self, other)

    def upstream(self, include_self: bool = True) -> "AssetSelection":
        """Transitive producers of the selected assets."""
        return _Closure(self, "up", include_self)

    def downstream(self, include_self: bool = True) -> "AssetSelection":
        """Transitive consumers of the selected assets (backfill cone)."""
        return _Closure(self, "down", include_self)

    # ------------------------------------------------------------ resolution
    def resolve(self, graph: "AssetGraph") -> list[str]:
        """Asset names selected by this expression, sorted.  Unknown
        explicit names raise with the available catalog."""
        return sorted(self._resolve(graph))

    def _resolve(self, graph: "AssetGraph") -> set[str]:
        raise NotImplementedError

    # --------------------------------------------------------------- parsing
    @staticmethod
    def parse(text: str) -> "AssetSelection":
        """Parse the CLI selection syntax (see module docstring)."""
        clauses = [c for c in re.split(r"[,\s]+", text.strip()) if c]
        if not clauses:
            raise ValueError("empty selection expression")
        out: AssetSelection | None = None
        for clause in clauses:
            out = AssetSelection._parse_clause(clause) if out is None \
                else out | AssetSelection._parse_clause(clause)
        return out

    @staticmethod
    def _parse_clause(clause: str) -> "AssetSelection":
        if clause == "*":
            return _All()
        m = _CLAUSE.match(clause)
        if not m:
            raise ValueError(f"bad selection clause {clause!r}")
        body = m.group("body")
        if body.startswith("tag:"):
            key, _, value = body[4:].partition("=")
            sel: AssetSelection = _Tag(key, value or None)
        elif body.startswith("group:"):
            sel = _Tag("group", body[6:])
        else:
            sel = _Keys((body,))
        # "+name+" means upstream-cone UNION downstream-cone of the base
        # selection, not the downstream closure of the upstream closure
        if m.group("up") and m.group("down"):
            return sel.upstream() | sel.downstream()
        if m.group("up"):
            return sel.upstream()
        if m.group("down"):
            return sel.downstream()
        return sel

    @staticmethod
    def coerce(obj: "AssetSelection | str | Iterable[str] | None",
               ) -> "AssetSelection":
        """Normalize every legacy ``targets`` spelling to a selection."""
        if obj is None:
            return _All()
        if isinstance(obj, AssetSelection):
            return obj
        if isinstance(obj, str):
            return AssetSelection.parse(obj)
        if isinstance(obj, (list, tuple, set, frozenset)):
            names = tuple(obj)
            if not all(isinstance(n, str) for n in names):
                raise TypeError(f"asset names must be strings: {names!r}")
            return _All() if not names else _Keys(names)
        raise TypeError(f"cannot coerce {type(obj).__name__!r} "
                        f"to an AssetSelection")


@dataclasses.dataclass(frozen=True)
class _All(AssetSelection):
    def _resolve(self, graph: "AssetGraph") -> set[str]:
        return set(graph.names())

    def __repr__(self) -> str:
        return "AssetSelection.all()"


@dataclasses.dataclass(frozen=True)
class _Keys(AssetSelection):
    names: tuple[str, ...]

    def _resolve(self, graph: "AssetGraph") -> set[str]:
        unknown = [n for n in self.names if n not in graph]
        if unknown:
            raise ValueError(
                f"unknown asset(s) {unknown} — available: "
                f"{sorted(graph.names())}")
        return set(self.names)

    def __repr__(self) -> str:
        return f"AssetSelection.assets({', '.join(map(repr, self.names))})"


@dataclasses.dataclass(frozen=True)
class _Tag(AssetSelection):
    key: str
    value: str | None = None

    def _resolve(self, graph: "AssetGraph") -> set[str]:
        out = set()
        for name in graph.names():
            for k, v in graph[name].tags:
                if k == self.key and (self.value is None or v == self.value):
                    out.add(name)
                    break
        return out

    def __repr__(self) -> str:
        val = "" if self.value is None else f", {self.value!r}"
        return f"AssetSelection.tag({self.key!r}{val})"


@dataclasses.dataclass(frozen=True)
class _Closure(AssetSelection):
    child: AssetSelection
    direction: str  # "up" | "down"
    include_self: bool = True

    def _resolve(self, graph: "AssetGraph") -> set[str]:
        base = self.child._resolve(graph)
        out = set(base) if self.include_self else set()
        walk = graph.upstream if self.direction == "up" else graph.downstream
        for name in base:
            out |= walk(name)
        return out

    def __repr__(self) -> str:
        op = "upstream" if self.direction == "up" else "downstream"
        return f"{self.child!r}.{op}()"


@dataclasses.dataclass(frozen=True)
class _Binary(AssetSelection):
    op: str  # "|" | "&" | "-"
    left: AssetSelection
    right: AssetSelection

    def _resolve(self, graph: "AssetGraph") -> set[str]:
        a, b = self.left._resolve(graph), self.right._resolve(graph)
        if self.op == "|":
            return a | b
        if self.op == "&":
            return a & b
        return a - b

    def __repr__(self) -> str:
        return f"({self.left!r} {self.op} {self.right!r})"
