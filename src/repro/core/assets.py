"""Software-defined assets + the asset graph (the Dagster layer).

An asset is a named computation with declared upstream deps, optional
partitioning, a compute profile (drives the cost model / platform choice),
a retry policy and platform hints.  ``@asset`` builds specs declaratively;
``AssetGraph`` validates the DAG and provides topological order.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable

from repro.core.partitions import PartitionsDefinition


@dataclasses.dataclass(frozen=True)
class ComputeProfile:
    """Work description used by the cost model.  Either analytic roofline
    terms (flops/bytes/collective_bytes per partition-run, whole-job) or a
    calibrated ``work_chip_hours`` shortcut for non-LM assets."""

    flops: float = 0.0
    bytes_hbm: float = 0.0
    collective_bytes: float = 0.0
    work_chip_hours: float = 0.0  # pre-calibrated work (Table-1 style assets)
    speedup_class: str = "scan"  # scan | shuffle | light | train | serve
    min_chips: int = 1
    memory_gb_per_chip: float = 0.0  # feasibility gate


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    max_attempts: int = 3
    backoff_s: float = 0.2
    failover_after: int = 2  # attempts on the chosen platform before rerouting
    backoff_cap_s: float = 30.0  # ceiling for the exponential schedule
    jitter: float = 0.25  # +/- fraction of the delay, deterministic per task

    def delay_s(self, attempt: int, key: tuple[str, str] = ("", "")) -> float:
        """Backoff before retry number ``attempt`` (1-based): capped
        exponential ``backoff_s * 2**(attempt-1)`` with deterministic jitter
        derived from ``key`` (asset, partition) — no RNG state, so reruns and
        tests reproduce the exact eligibility schedule while distinct tasks
        retrying after the same platform hiccup decorrelate instead of
        stampeding back together."""
        if self.backoff_s <= 0.0:
            return 0.0
        base = min(self.backoff_s * (2.0 ** max(0, attempt - 1)),
                   self.backoff_cap_s)
        if self.jitter <= 0.0:
            return base
        # blake2b is stable across processes (unlike hash()), cheap, and
        # keyed only by task identity + attempt so a given retry always
        # lands at the same offset in [-jitter, +jitter].
        digest = hashlib.blake2b(
            f"{key[0]}|{key[1]}|{attempt}".encode(), digest_size=8).digest()
        unit = int.from_bytes(digest, "big") / float(2 ** 64)  # [0, 1)
        return base * (1.0 + self.jitter * (2.0 * unit - 1.0))


@dataclasses.dataclass(frozen=True)
class AssetSpec:
    name: str
    fn: Callable[..., Any]
    deps: tuple[str, ...] = ()
    partitions: PartitionsDefinition | None = None
    compute: ComputeProfile = ComputeProfile()
    retry: RetryPolicy = RetryPolicy()
    platform_hint: str | None = None  # pin to a platform (overrides factory)
    tags: tuple[tuple[str, str], ...] = ()
    version: str = "1"  # bump to invalidate cached materializations


def asset(name: str | None = None, deps: tuple[str, ...] = (),
          partitions: PartitionsDefinition | None = None,
          compute: ComputeProfile | None = None,
          retry: RetryPolicy | None = None,
          platform_hint: str | None = None,
          tags: dict[str, str] | None = None,
          version: str = "1"):
    """Decorator: ``fn(ctx, **dep_values) -> value``."""

    def deco(fn: Callable[..., Any]) -> AssetSpec:
        return AssetSpec(
            name=name or fn.__name__,
            fn=fn,
            deps=tuple(deps),
            partitions=partitions,
            compute=compute or ComputeProfile(),
            retry=retry or RetryPolicy(),
            platform_hint=platform_hint,
            tags=tuple(sorted((tags or {}).items())),
            version=version,
        )

    return deco


class AssetGraph:
    def __init__(self, assets: list[AssetSpec] | None = None):
        self._assets: dict[str, AssetSpec] = {}
        # reverse adjacency (producer -> consumers), maintained on add() so
        # downstream() never rescans the whole asset table
        self._children: dict[str, list[str]] = {}
        for a in assets or []:
            self.add(a)

    def add(self, spec: AssetSpec) -> AssetSpec:
        if spec.name in self._assets:
            raise ValueError(f"duplicate asset {spec.name!r}")
        self._assets[spec.name] = spec
        for d in spec.deps:
            self._children.setdefault(d, []).append(spec.name)
        return spec

    def __getitem__(self, name: str) -> AssetSpec:
        return self._assets[name]

    def __contains__(self, name: str) -> bool:
        return name in self._assets

    def names(self) -> list[str]:
        return list(self._assets)

    def validate(self) -> None:
        for a in self._assets.values():
            for d in a.deps:
                if d not in self._assets:
                    raise ValueError(f"asset {a.name!r} depends on unknown {d!r}")
        self.topo_order()  # raises on cycles

    def topo_order(self, targets: list[str] | None = None) -> list[str]:
        """Kahn topological order restricted to targets + their ancestors."""
        want = set(targets or self._assets)
        frontier = list(want)
        while frontier:
            n = frontier.pop()
            for d in self._assets[n].deps:
                if d not in want:
                    want.add(d)
                    frontier.append(d)
        indeg = {n: 0 for n in want}
        out: dict[str, list[str]] = {n: [] for n in want}
        for n in want:
            for d in self._assets[n].deps:
                indeg[n] += 1
                out[d].append(n)
        ready = sorted(n for n, k in indeg.items() if k == 0)
        order = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for m in sorted(out[n]):
                indeg[m] -= 1
                if indeg[m] == 0:
                    ready.append(m)
        if len(order) != len(want):
            cyc = sorted(set(want) - set(order))
            raise ValueError(f"cycle detected among {cyc}")
        return order

    def children(self, name: str) -> tuple[str, ...]:
        """Direct consumers of ``name`` (memoized reverse edges)."""
        return tuple(self._children.get(name, ()))

    def downstream(self, name: str) -> set[str]:
        """Transitive consumers of ``name`` (excluding ``name``), via the
        memoized reverse adjacency — iterative, O(edges in the cone), where
        the old recursive version rescanned every asset per call (quadratic
        on deep graphs)."""
        out: set[str] = set()
        stack = [name]
        while stack:
            for c in self._children.get(stack.pop(), ()):
                if c not in out:
                    out.add(c)
                    stack.append(c)
        return out

    def upstream(self, name: str) -> set[str]:
        """Transitive producers of ``name`` (excluding ``name``)."""
        out: set[str] = set()
        stack = [name]
        while stack:
            for d in self._assets[stack.pop()].deps:
                if d not in out:
                    out.add(d)
                    stack.append(d)
        return out
