"""Context Injector (paper component #1): builds the per-run context —
partition key, environment layering, tags, platform + mesh config — and
injects it as the first argument of every asset function.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any

from repro.core.assets import AssetSpec
from repro.core.platforms import Platform
from repro.core.telemetry import MessageReader


@dataclasses.dataclass
class RunContext:
    run_id: str
    asset: str
    partition_key: str
    platform: Platform
    attempt: int
    env: dict[str, str]
    tags: dict[str, str]
    artifacts_dir: str
    reader: MessageReader | None = None

    @property
    def partition_dims(self) -> dict[str, str]:
        """Split a multi-partition key 'a/b' into named dims when possible."""
        if "/" in self.partition_key:
            parts = self.partition_key.split("/")
            names = ["time", "domain"][: len(parts)]
            return dict(zip(names, parts))
        return {"key": self.partition_key}

    def log(self, kind: str, **payload: Any) -> None:
        if self.reader is not None:
            self.reader.emit(self.run_id, self.asset, self.partition_key,
                             self.platform.name, kind, **payload)

    def heartbeat(self, **payload: Any) -> None:
        self.log("HEARTBEAT", **payload)


class ContextInjector:
    """Layered env/config injection: base env < platform env < asset tags
    < per-run overrides (the paper's 'general and job-specific
    configurations, including environmental variables, partitioning and
    tagging')."""

    def __init__(self, base_env: dict[str, str] | None = None,
                 artifacts_root: str = "artifacts/runs",
                 reader: MessageReader | None = None):
        self.base_env = dict(base_env or {})
        self.artifacts_root = artifacts_root
        self.reader = reader

    def build(self, run_id: str, spec: AssetSpec, partition_key: str,
              platform: Platform, attempt: int,
              overrides: dict[str, str] | None = None) -> RunContext:
        env = dict(self.base_env)
        env.update({
            "REPRO_PLATFORM": platform.name,
            "REPRO_MESH": "x".join(map(str, platform.mesh_shape)),
            "REPRO_PARTITION": partition_key,
        })
        env.update(overrides or {})
        tags = dict(spec.tags)
        tags.setdefault("asset", spec.name)
        tags.setdefault("speedup_class", spec.compute.speedup_class)
        art = os.path.join(self.artifacts_root, run_id,
                           spec.name, partition_key.replace("/", "_"))
        return RunContext(
            run_id=run_id, asset=spec.name, partition_key=partition_key,
            platform=platform, attempt=attempt, env=env, tags=tags,
            artifacts_dir=art, reader=self.reader)
