"""Dynamic Factory for Cloud Client Management (paper component #5).

"Detects and designates appropriate execution environments, adapting to
changes in processing requirements or platform preferences" — here: a
cost-model argmin over the platform catalog under a pluggable objective,
with per-asset pinning (platform_hint), deny-lists (e.g. after repeated
failures the coordinator reroutes), and client caching.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.assets import AssetSpec
from repro.core.clients import (LocalClient, PlatformClient,
                                SimulatedClusterClient)
from repro.core.costmodel import CostEstimate, CostModel
from repro.core.faults import FaultPlan
from repro.core.platforms import Platform


@dataclasses.dataclass(frozen=True)
class Objective:
    """score = expected_cost + time_value_usd_per_hour * duration.

    min_cost  -> time_value 0 (the budget-conscious EMR regime)
    min_time  -> huge time_value (the DBR regime)
    balanced  -> the paper's operating point: deadlines matter, money matters.

    ``budget_usd`` / ``deadline_s`` are *run-level* constraints: the greedy
    per-task ``choose`` cannot see them (it scores tasks in isolation), so
    they only bind through the DAG-level ``RunPlanner``, which marks a plan
    infeasible when they cannot be met.
    """

    name: str
    time_value_usd_per_hour: float
    budget_usd: float | None = None
    deadline_s: float | None = None

    @staticmethod
    def min_cost() -> "Objective":
        return Objective("min_cost", 0.0)

    @staticmethod
    def min_time() -> "Objective":
        return Objective("min_time", 1e9)

    @staticmethod
    def balanced(usd_per_hour: float = 60.0) -> "Objective":
        return Objective("balanced", usd_per_hour)

    def constrained(self, budget_usd: float | None = None,
                    deadline_s: float | None = None) -> "Objective":
        """Copy with run-level budget/deadline constraints attached."""
        return dataclasses.replace(self, budget_usd=budget_usd,
                                   deadline_s=deadline_s)


class DynamicClientFactory:
    def __init__(self, catalog: dict[str, Platform], cost_model: CostModel,
                 objective: Objective,
                 client_builder: Callable[[Platform], PlatformClient] | None = None,
                 sim_seed: int = 0, sim_time_scale: float = 0.0,
                 faults: "FaultPlan | None" = None):
        self.catalog = dict(catalog)
        self.cost_model = cost_model
        self.objective = objective
        self._clients: dict[str, PlatformClient] = {}
        self._builder = client_builder
        self.sim_seed = sim_seed
        self.sim_time_scale = sim_time_scale
        #: seeded chaos plan (core/faults.py): client-level overrides win
        #: over both the default builder and a custom ``client_builder``,
        #: so one FaultPlan degrades a platform for every consumer
        self.faults = faults

    # ----------------------------------------------------------- selection
    def estimates(self, spec: AssetSpec) -> dict[str, CostEstimate]:
        return {name: self.cost_model.estimate(spec, p)
                for name, p in self.catalog.items()}

    def score(self, spec: AssetSpec, platform: Platform) -> tuple[float, CostEstimate]:
        est = self.cost_model.estimate(spec, platform)
        if not est.feasible:
            return float("inf"), est
        exp_cost = self.cost_model.expected_cost_with_retries(
            est, platform, spec.name)
        score = exp_cost + self.objective.time_value_usd_per_hour * (
            est.duration_s / 3600.0)
        return score, est

    def choose(self, spec: AssetSpec,
               deny: set[str] | None = None) -> tuple[Platform, CostEstimate]:
        deny = deny or set()
        if spec.platform_hint and spec.platform_hint not in deny \
                and spec.platform_hint in self.catalog:
            p = self.catalog[spec.platform_hint]
            return p, self.cost_model.estimate(spec, p)
        best: tuple[float, str, CostEstimate] | None = None
        for name, p in self.catalog.items():
            if name in deny:
                continue
            s, est = self.score(spec, p)
            if best is None or s < best[0]:
                best = (s, name, est)
        if best is None or best[0] == float("inf"):
            raise RuntimeError(
                f"no feasible platform for asset {spec.name!r} (deny={deny})")
        return self.catalog[best[1]], best[2]

    # -------------------------------------------------------------- clients
    def client(self, platform: Platform) -> PlatformClient:
        if platform.name not in self._clients:
            cf = (self.faults.client_faults(platform.name)
                  if self.faults is not None else None)
            if cf is not None:
                # deterministic degraded reality for this platform: the
                # catalog's beliefs stay untouched (that gap is the point)
                self._clients[platform.name] = SimulatedClusterClient(
                    platform, seed=self.sim_seed,
                    sim_time_scale=self.sim_time_scale,
                    failure_rate=cf.failure_rate,
                    preemption_rate=cf.preemption_rate,
                    duration_bias=cf.slowdown)
            elif self._builder is not None:
                self._clients[platform.name] = self._builder(platform)
            elif platform.kind == "local":
                self._clients[platform.name] = LocalClient(platform)
            else:
                self._clients[platform.name] = SimulatedClusterClient(
                    platform, seed=self.sim_seed,
                    sim_time_scale=self.sim_time_scale)
        return self._clients[platform.name]
