"""Closed-loop adaptive orchestration: online cost model, drift detection,
and per-platform circuit breakers.

The planner's wins assume catalog beliefs (duration, failure/preemption
rates) match reality; the paper's own Fig-3 data shows they drift.  This
module closes the loop:

- ``OnlineCostModel`` wraps the static ``CostModel`` with per-(asset,
  platform) EWMA estimates of the realized/predicted duration ratio and the
  observed single-attempt success rate, learned from ``MessageReader``
  ``COST`` events.  Both scalar ``estimate`` and vectorized
  ``estimate_batch`` apply the same corrections (via the ``_dur_ratio_col``
  / ``_p_ok_col`` hooks), so planner pricing stays bit-consistent with the
  scalar path — and with *zero* observations the model is bit-identical to
  the static one.
- ``DriftDetector`` fires when a learned duration ratio breaches the
  threshold relative to its value at the last plan, when a platform takes a
  burst of hard failures, or when preemptions streak.  Each firing hands
  ``RunCoordinator`` a reason list; the coordinator re-runs ``RunPlanner``
  over not-yet-launched tasks.
- ``CircuitBreaker`` (closed -> open after N consecutive hard failures ->
  half-open probe after a cooldown) evicts a sick platform *fleet-wide*
  through the factory deny machinery, instead of every task rediscovering
  the sickness through its own retry budget.
- ``AdaptiveController`` glues the three together behind a seq-cursor over
  the telemetry stream, with replan rate limiting.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Sequence

import numpy as np

from repro.core.assets import AssetSpec
from repro.core.costmodel import CostEstimate, CostModel
from repro.core.factory import DynamicClientFactory
from repro.core.platforms import Platform
from repro.core.telemetry import MessageReader


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Tuning knobs for the closed loop (defaults are benchmark-calibrated)."""

    #: EWMA smoothing for duration ratios and success observations.
    ewma_alpha: float = 0.3
    #: pseudo-observation count of the catalog prior: with n real
    #: observations the blend weight on observed data is n / (n + prior).
    prior_strength: float = 4.0
    #: observations of an (asset, platform) cell before its ratio can
    #: trigger drift.
    min_observations: int = 3
    #: realized/predicted ratio change (vs the last plan's baseline) that
    #: counts as drift, symmetric: fire at >= x or <= 1/x.
    ratio_threshold: float = 1.4
    #: hard failures within ``burst_window`` recent outcomes on one
    #: platform that count as a failure burst.
    failure_burst: int = 3
    burst_window: int = 12
    #: consecutive preemptions on one platform that count as drift.
    preemption_streak: int = 3
    #: consecutive hard failures that trip a breaker open.
    breaker_failures: int = 3
    #: wall-clock seconds an open breaker waits before allowing a
    #: half-open probe.
    breaker_cooldown_s: float = 30.0
    #: replan rate limiting.
    max_replans: int = 8
    replan_cooldown_s: float = 0.25
    #: expected fraction of an attempt lost on failure/preemption (the
    #: simulated clients inject uniform(0.2, 0.8) partial progress).
    rework_fraction: float = 0.5
    #: learned duration ratios are clamped into this range.
    ratio_min: float = 0.05
    ratio_max: float = 20.0


class _Ewma:
    """Exponentially-weighted mean with an observation count."""

    __slots__ = ("alpha", "mean", "n")

    def __init__(self, alpha: float):
        self.alpha = alpha
        self.mean = 0.0
        self.n = 0

    def update(self, x: float) -> None:
        self.mean = x if self.n == 0 else (
            self.alpha * x + (1.0 - self.alpha) * self.mean)
        self.n += 1


class OnlineCostModel(CostModel):
    """``CostModel`` + per-(asset, platform) corrections learned online.

    Duration: every component of a ``CostEstimate`` is scaled by the
    clamped, prior-blended EWMA of realized/predicted duration ratios.
    Reliability: ``_p_ok`` blends the catalog's ``Platform.p_success`` with
    the observed success rate (weight n / (n + prior_strength)), feeding
    both the retry-aware expected cost and the rework-aware
    ``schedule_duration``.

    Bit-consistency contract: with zero observations every output is
    bit-identical to the wrapped static model, and ``estimate_batch`` always
    equals scalar ``estimate`` cell-for-cell (the batch path scales the same
    component floats in the same order — see ``CostModel._dur_ratio_col``).
    """

    def __init__(self, base: CostModel | None = None,
                 config: AdaptiveConfig = AdaptiveConfig()):
        base = base or CostModel()
        super().__init__(hbm_gb_per_chip=base.hbm_gb,
                         target_hours=base.target_hours,
                         rework_fraction=config.rework_fraction)
        self.config = config
        # hierarchical state: per-(asset, platform) cells shrink toward a
        # per-platform level, which shrinks toward the catalog prior — so
        # drift observed on one asset informs pricing of *other* assets on
        # the same platform before they ever run there
        self._ratio: dict[tuple[str, str], _Ewma] = {}
        self._ok: dict[tuple[str, str], _Ewma] = {}
        self._plat_ratio: dict[str, _Ewma] = {}
        self._plat_ok: dict[str, _Ewma] = {}

    # ------------------------------------------------------------- learning
    def observe(self, asset: str, platform: str, outcome: str,
                predicted_s: float = 0.0, realized_s: float = 0.0) -> None:
        """Fold one terminal attempt outcome into the model.  ``outcome``
        is an ``OUTCOME_KEYS`` bucket; duration ratios only learn from
        successes (failed attempts ran a partial, unknown fraction)."""
        if outcome == "cancelled":
            return
        key = (asset, platform)
        ok = self._ok.get(key)
        if ok is None:
            ok = self._ok[key] = _Ewma(self.config.ewma_alpha)
        ok.update(1.0 if outcome == "success" else 0.0)
        pok = self._plat_ok.get(platform)
        if pok is None:
            pok = self._plat_ok[platform] = _Ewma(self.config.ewma_alpha)
        pok.update(1.0 if outcome == "success" else 0.0)
        if outcome == "success" and predicted_s > 1e-6 and realized_s > 0.0:
            ratio = self._ratio.get(key)
            if ratio is None:
                ratio = self._ratio[key] = _Ewma(self.config.ewma_alpha)
            ratio.update(realized_s / predicted_s)
            pratio = self._plat_ratio.get(platform)
            if pratio is None:
                pratio = self._plat_ratio[platform] = _Ewma(
                    self.config.ewma_alpha)
            pratio.update(realized_s / predicted_s)

    def observations(self, asset: str, platform: str) -> int:
        e = self._ok.get((asset, platform))
        return e.n if e else 0

    def duration_ratio(self, asset: str | None, platform: str) -> float:
        """Hierarchically blended, clamped realized/predicted duration ratio
        for one (asset, platform) cell: catalog prior (1.0) <- platform-level
        EWMA <- cell EWMA, each shrunk by n / (n + prior_strength).  Exactly
        1.0 with no observations anywhere on the platform."""
        base = 1.0
        ep = self._plat_ratio.get(platform)
        if ep is not None and ep.n > 0:
            wp = ep.n / (ep.n + self.config.prior_strength)
            base = (1.0 - wp) * 1.0 + wp * ep.mean
        e = self._ratio.get((asset, platform)) if asset is not None else None
        if e is None or e.n == 0:
            r = base
        else:
            w = e.n / (e.n + self.config.prior_strength)
            r = (1.0 - w) * base + w * e.mean
        if r == 1.0:
            return 1.0  # keep the pristine fast path bit-exact
        return min(max(r, self.config.ratio_min), self.config.ratio_max)

    def ratios(self) -> dict[tuple[str, str], tuple[float, int]]:
        """Every learned (asset, platform) -> (blended ratio, n_obs)."""
        return {k: (self.duration_ratio(*k), e.n)
                for k, e in self._ratio.items()}

    # ------------------------------------------------------------- pricing
    def _p_ok(self, platform: Platform, asset: str | None = None) -> float:
        prior = platform.p_success()
        ep = self._plat_ok.get(platform.name)
        if ep is not None and ep.n > 0:
            wp = ep.n / (ep.n + self.config.prior_strength)
            prior = (1.0 - wp) * prior + wp * ep.mean
        e = self._ok.get((asset, platform.name)) if asset is not None else None
        if e is None or e.n == 0:
            p = prior
        else:
            w = e.n / (e.n + self.config.prior_strength)
            p = (1.0 - w) * prior + w * e.mean
        return max(1e-3, min(1.0, p))

    def _p_ok_col(self, platform: Platform,
                  specs: Sequence[AssetSpec]) -> np.ndarray:
        return np.array([self._p_ok(platform, s.name) for s in specs],
                        dtype=np.float64)

    def _dur_ratio_col(self, platform: Platform,
                       specs: Sequence[AssetSpec]) -> np.ndarray | None:
        if not self._ratio and not self._plat_ratio:
            return None  # pristine: stay byte-identical to the static path
        return np.array(
            [self.duration_ratio(s.name, platform.name) for s in specs],
            dtype=np.float64)

    def estimate(self, asset: AssetSpec, platform: Platform) -> CostEstimate:
        est = super().estimate(asset, platform)
        r = self.duration_ratio(asset.name, platform.name)
        if not est.feasible or r == 1.0:
            return est
        # scale each component (total re-derives as (base+surcharge)+storage
        # via the property) — the batch path mirrors this exactly
        return dataclasses.replace(
            est, duration_s=est.duration_s * r, compute_s=est.compute_s * r,
            base_usd=est.base_usd * r, surcharge_usd=est.surcharge_usd * r,
            storage_usd=est.storage_usd * r)


class DriftDetector:
    """Decides *when* the current plan's assumptions are stale enough to pay
    for a replan: duration-ratio breaches vs the last plan's baseline,
    hard-failure bursts, and preemption streaks (all per platform or
    per (asset, platform))."""

    def __init__(self, model: OnlineCostModel,
                 config: AdaptiveConfig = AdaptiveConfig()):
        self.model = model
        self.cfg = config
        self._baseline: dict[tuple[str, str], float] = {}
        self._recent: dict[str, deque[int]] = {}  # platform -> 1=hard failure
        self._streak: dict[str, int] = {}  # platform -> consecutive preempts

    def observe(self, asset: str, platform: str, outcome: str) -> None:
        if outcome == "cancelled":
            return
        window = self._recent.get(platform)
        if window is None:
            window = self._recent[platform] = deque(
                maxlen=self.cfg.burst_window)
        window.append(1 if outcome == "failure" else 0)
        if outcome == "preemption":
            self._streak[platform] = self._streak.get(platform, 0) + 1
        else:
            self._streak[platform] = 0

    def check(self) -> list[str]:
        """Reasons to replan right now (empty = assumptions still hold)."""
        reasons: list[str] = []
        thr = self.cfg.ratio_threshold
        for (asset, plat), (ratio, n) in sorted(self.model.ratios().items()):
            if n < self.cfg.min_observations:
                continue
            base = self._baseline.get((asset, plat), 1.0)
            rel = ratio / max(base, 1e-9)
            if rel >= thr or rel <= 1.0 / thr:
                reasons.append(f"duration drift {asset}@{plat}: "
                               f"ratio {ratio:.2f} (baseline {base:.2f})")
        for plat in sorted(self._recent):
            if sum(self._recent[plat]) >= self.cfg.failure_burst:
                reasons.append(
                    f"failure burst on {plat}: "
                    f"{sum(self._recent[plat])} hard failures in last "
                    f"{len(self._recent[plat])} outcomes")
        for plat in sorted(self._streak):
            if self._streak[plat] >= self.cfg.preemption_streak:
                reasons.append(f"preemption streak on {plat}: "
                               f"{self._streak[plat]} consecutive")
        return reasons

    def mark_replanned(self) -> None:
        """Re-baseline: the new plan already prices current beliefs, so the
        same drift must not re-trigger forever."""
        self._baseline = {k: r for k, (r, _n) in self.model.ratios().items()}
        self._recent.clear()
        self._streak.clear()


class CircuitBreaker:
    """closed -> open (after N consecutive hard failures) -> half-open
    (single probe after ``cooldown_s``) -> closed on probe success / back to
    open on probe failure.  Preemptions are neutral: expected on spot
    capacity, they neither trip nor reset the breaker."""

    def __init__(self, platform: str, failures: int = 3,
                 cooldown_s: float = 30.0):
        self.platform = platform
        self.failures = failures
        self.cooldown_s = cooldown_s
        self.state = "closed"
        self.consecutive = 0
        self.opened_at = 0.0
        self.probe_inflight = False
        self.trips = 0

    def record(self, outcome: str, now: float) -> str | None:
        """Fold a terminal outcome in; returns the new state on transition
        (``None`` when nothing changed)."""
        if outcome == "cancelled" or outcome == "preemption":
            return None
        if outcome == "success":
            self.consecutive = 0
            if self.state != "closed":
                self.state = "closed"
                self.probe_inflight = False
                return "closed"
            return None
        # hard failure
        self.consecutive += 1
        if self.state == "half-open":
            self.state = "open"
            self.opened_at = now
            self.probe_inflight = False
            self.trips += 1
            return "open"
        if self.state == "closed" and self.consecutive >= self.failures:
            self.state = "open"
            self.opened_at = now
            self.trips += 1
            return "open"
        return None

    def allow(self, now: float) -> bool:
        """May the fleet launch on this platform right now?  An open breaker
        past its cooldown flips to half-open and admits a single probe."""
        if self.state == "closed":
            return True
        if self.state == "open":
            if now - self.opened_at >= self.cooldown_s:
                self.state = "half-open"
                self.probe_inflight = False
                return True
            return False
        return not self.probe_inflight  # half-open: one probe at a time

    def note_launch(self, now: float) -> None:
        if self.state == "half-open":
            self.probe_inflight = True


class AdaptiveController:
    """Glue: consumes the telemetry stream incrementally (seq cursor),
    feeds the online model / drift detector / breakers, and rate-limits
    replan decisions for ``RunCoordinator``."""

    def __init__(self, catalog: dict[str, Platform],
                 cost_model: CostModel | None = None,
                 config: AdaptiveConfig = AdaptiveConfig()):
        self.cfg = config
        self.model = OnlineCostModel(base=cost_model, config=config)
        self.detector = DriftDetector(self.model, config)
        self.breakers = {name: CircuitBreaker(
            name, failures=config.breaker_failures,
            cooldown_s=config.breaker_cooldown_s) for name in catalog}
        self._cursor = 0
        self.replans = 0
        self._last_replan = -math.inf
        self.replan_log: list[dict] = []

    # ------------------------------------------------------------ telemetry
    def ingest(self, reader: MessageReader) -> tuple[int, list[tuple[str, str]]]:
        """Consume new events; returns (#outcomes folded in, breaker
        transitions as (platform, new_state))."""
        outcomes = 0
        transitions: list[tuple[str, str]] = []
        for e in reader.events_since(self._cursor):
            self._cursor = e.seq + 1
            if e.kind != "COST":
                continue
            outcome = e.payload.get("outcome")
            if not outcome:
                continue  # pre-adaptive emitter: nothing to learn from
            if e.payload.get("prepaid"):
                # resumed re-execution of an attempt the crashed run billed:
                # its journal BILL was already folded in by ``warm_start``
                continue
            outcomes += 1
            self.model.observe(e.asset, e.platform, outcome,
                               predicted_s=e.payload.get("est_duration_s", 0.0),
                               realized_s=e.payload.get("duration_s", 0.0))
            self.detector.observe(e.asset, e.platform, outcome)
            br = self.breakers.get(e.platform)
            if br is not None:
                t = br.record(outcome, now=e.ts)
                if t is not None:
                    transitions.append((e.platform, t))
        return outcomes, transitions

    def warm_start(self, bills: list[dict]) -> None:
        """Resume support: fold a crashed run's journaled BILL records in
        as though their COST events had been ingested live, so the
        replacement run starts with everything the dead run learned
        (duration ratios, success rates, breaker states) instead of the
        static catalog priors."""
        for b in bills:
            p = b["payload"]
            outcome = p.get("outcome")
            if not outcome:
                continue
            self.model.observe(b["asset"], b["platform"], outcome,
                               predicted_s=p.get("est_duration_s", 0.0),
                               realized_s=p.get("sim_duration_s", 0.0))
            self.detector.observe(b["asset"], b["platform"], outcome)
            br = self.breakers.get(b["platform"])
            if br is not None:
                br.record(outcome, now=b.get("ts", 0.0))

    # ------------------------------------------------------------- breakers
    def open_platforms(self, now: float) -> set[str]:
        """Platforms the fleet must not launch on right now."""
        return {name for name, b in self.breakers.items() if not b.allow(now)}

    def note_launch(self, platform: str, now: float) -> None:
        br = self.breakers.get(platform)
        if br is not None:
            br.note_launch(now)

    # -------------------------------------------------------------- replans
    def should_replan(self, now: float) -> list[str]:
        """Drift reasons if a replan is warranted *and* allowed (rate
        limits: ``max_replans`` total, ``replan_cooldown_s`` between)."""
        if self.replans >= self.cfg.max_replans:
            return []
        if now - self._last_replan < self.cfg.replan_cooldown_s:
            return []
        return self.detector.check()

    def note_replanned(self, now: float, reasons: list[str],
                       adopted: bool) -> None:
        self.replans += 1
        self._last_replan = now
        self.detector.mark_replanned()
        self.replan_log.append({"at": now, "reasons": reasons,
                                "adopted": adopted})

    # ------------------------------------------------------------- planning
    def planning_factory(self, factory: DynamicClientFactory,
                         now: float) -> DynamicClientFactory:
        """A pricing view of ``factory`` for the planner: the online cost
        model plus the catalog minus open-breaker platforms (kept whole if
        that would empty it — a sick platform beats no platform)."""
        open_p = self.open_platforms(now)
        catalog = {n: p for n, p in factory.catalog.items() if n not in open_p}
        if not catalog:
            catalog = dict(factory.catalog)
        return DynamicClientFactory(
            catalog, self.model, factory.objective,
            sim_seed=factory.sim_seed, sim_time_scale=factory.sim_time_scale)
