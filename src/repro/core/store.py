"""Materialization store: lineage-tracked, content-fingerprinted asset
outputs with freshness-based caching (the Delta-Lake-table analogue).

The fingerprint of a materialization is hash(asset version, partition,
upstream fingerprints); an asset run is skipped when a materialization with
the current fingerprint already exists — the paper's reproducibility story
("replication of scientific experiments under identical conditions").
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import threading
import time
from typing import Any


class MaterializationStore:
    def __init__(self, directory: str | None = None):
        self.dir = directory
        self._mem: dict[tuple[str, str], dict] = {}
        self._lock = threading.Lock()
        if directory:
            os.makedirs(directory, exist_ok=True)

    @staticmethod
    def fingerprint(version: str, partition: str,
                    upstream: dict[str, str]) -> str:
        blob = json.dumps({"v": version, "p": partition,
                           "up": dict(sorted(upstream.items()))},
                          sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def _key(self, asset: str, partition: str) -> tuple[str, str]:
        return (asset, partition)

    def put(self, asset: str, partition: str, value: Any, fingerprint: str,
            meta: dict | None = None) -> dict:
        rec = {
            "asset": asset, "partition": partition,
            "fingerprint": fingerprint, "time": time.time(),
            "meta": meta or {},
        }
        if self.dir:
            fname = f"{asset}__{partition.replace('/', '_')}__{fingerprint}.pkl"
            path = os.path.join(self.dir, fname)
            with open(path + ".tmp", "wb") as f:
                pickle.dump(value, f)
            os.replace(path + ".tmp", path)
            rec["path"] = path
        else:
            rec["value"] = value
        with self._lock:
            self._mem[self._key(asset, partition)] = rec
        return rec

    def get(self, asset: str, partition: str) -> Any:
        with self._lock:
            rec = self._mem.get(self._key(asset, partition))
        if rec is None:
            raise KeyError(f"no materialization for {asset}[{partition}]")
        if "value" in rec:
            return rec["value"]
        with open(rec["path"], "rb") as f:
            return pickle.load(f)

    def record(self, asset: str, partition: str) -> dict | None:
        with self._lock:
            return self._mem.get(self._key(asset, partition))

    def is_fresh(self, asset: str, partition: str, fingerprint: str) -> bool:
        rec = self.record(asset, partition)
        return rec is not None and rec["fingerprint"] == fingerprint
