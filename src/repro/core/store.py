"""Content-addressed, cross-run materialization store (the Delta-Lake-table
analogue, rebuilt for incremental materialization).

The fingerprint of a materialization is

    hash(code version, partition, upstream *data* hashes)

where the code version folds the asset's declared ``version`` string with a
hash of its compute function's source, and the upstream entries are content
hashes of the upstream *values* — not their fingerprint chains.  That buys
two properties the old version-chain store could not offer:

* **cross-run caching** — records live in a persistent, atomically rewritten
  JSON index (``<dir>/index.json``) beside content-hashed blobs
  (``<dir>/blobs/<data_hash>.pkl``), reloaded on open, so a second process
  sees the first's materializations;
* **early cutoff** — an upstream that *rematerializes byte-identical data*
  leaves its data hash unchanged, so downstream fingerprints still match and
  the downstream cone is skipped even though the upstream re-ran.

``resolve_staleness`` walks the (asset, partition) task DAG against a store
and labels every task fresh or stale with a reason (never-materialized /
code-changed / upstream-data-changed / upstream-stale / forced); the
coordinator uses it to skip fresh work up front and the planner to price
fresh tasks at ~0 (see planner.py).  A *missing* upstream record always
forces staleness — there is no placeholder hash that could masquerade as a
real one.
"""
from __future__ import annotations

import dataclasses
import hashlib
import inspect
import json
import os
import pickle
import textwrap
import threading
import time
import warnings
from typing import TYPE_CHECKING, Any, Callable

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (assets -> store)
    from repro.core.assets import AssetGraph, AssetSpec

TaskKey = tuple[str, str]  # (asset, partition)

_INDEX = "index.json"
_BLOBS = "blobs"


def _short(h: "hashlib._Hash") -> str:
    return h.hexdigest()[:16]


class StoreCorruption(UserWarning):
    """On-disk store state (index or blob) failed integrity validation."""


def _quarantine(path: str, suffix: str = "corrupt") -> str | None:
    """Move a damaged file aside as ``<path>.<suffix>-<n>`` (never clobbers
    an earlier quarantine) so post-mortems keep the evidence while the
    store carries on without it."""
    n = 0
    while True:
        target = f"{path}.{suffix}-{n}"
        if not os.path.exists(target):
            break
        n += 1
    try:
        os.replace(path, target)
    except OSError:
        return None
    return target


def _durable_write(path: str, data: bytes) -> None:
    """Crash-safe file publish: write tmp, flush+fsync, rename, fsync the
    directory.  Without the fsyncs, ``os.replace`` alone can leave a
    zero-length (or stale) file *behind the final name* after power loss —
    the rename may hit disk before the data does."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    try:
        fd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
    except OSError:  # pragma: no cover - non-posix directory open
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


_source_hash_cache: dict[Callable, str] = {}


def source_hash(fn: Callable[..., Any]) -> str:
    """Stable hash of a function's source text (dedented), falling back to
    its bytecode when source is unavailable (REPL, C callables)."""
    try:
        cached = _source_hash_cache.get(fn)
    except TypeError:  # unhashable callable
        cached = None
    if cached is not None:
        return cached
    try:
        src = textwrap.dedent(inspect.getsource(fn))
    except (OSError, TypeError):
        code = getattr(fn, "__code__", None)
        src = (code.co_code.hex() + repr(code.co_consts)
               if code is not None else repr(fn))
    out = _short(hashlib.sha1(src.encode()))
    try:
        _source_hash_cache[fn] = out
    except TypeError:
        pass
    return out


def code_version(spec: "AssetSpec") -> str:
    """Asset code identity: declared version string + compute-fn source hash.
    Editing the function body or bumping ``version`` both invalidate."""
    return f"{spec.version}:{source_hash(spec.fn)}"


@dataclasses.dataclass(frozen=True)
class Staleness:
    """Resolution verdict for one (asset, partition) task."""

    fresh: bool
    reason: str  # fresh | never-materialized | code-changed |
    #             upstream-data-changed | upstream-stale:<task> |
    #             missing-upstream:<task> | forced | invalidated
    fingerprint: str = ""  # expected fingerprint ("" when unknowable)


class MaterializationStore:
    """Content-addressed materialization records, optionally disk-backed.

    With ``directory`` set, the index is loaded on open and every ``put`` /
    ``invalidate`` atomically rewrites ``index.json`` (tmp + ``os.replace``),
    so concurrent readers never observe a torn index and a store opened
    later on the same directory sees all prior materializations.  Blobs are
    named by their content hash: identical values share one blob.
    """

    def __init__(self, directory: str | None = None):
        self.dir = directory
        self._mem: dict[TaskKey, dict] = {}
        self._lock = threading.Lock()
        self._index_mtime = 0.0
        if directory:
            os.makedirs(os.path.join(directory, _BLOBS), exist_ok=True)
            self._load_index()

    # ------------------------------------------------------------ fingerprint
    @staticmethod
    def data_fingerprint(value: Any) -> tuple[bytes, str]:
        """Pickle a value and content-hash the blob: (blob, data_hash)."""
        blob = pickle.dumps(value, protocol=4)
        return blob, _short(hashlib.sha1(blob))

    @staticmethod
    def fingerprint(code_version: str, partition: str,
                    upstream: dict[str, str]) -> str:
        """hash(code version, partition, upstream data hashes).  ``upstream``
        maps "dep[partition]" -> that materialization's ``data_hash``; a
        missing upstream has no representation here by design — callers must
        treat it as unconditionally stale instead of inventing a filler."""
        blob = json.dumps({"v": code_version, "p": partition,
                           "up": dict(sorted(upstream.items()))},
                          sort_keys=True)
        return _short(hashlib.sha1(blob.encode()))

    # ------------------------------------------------------------ index io
    def _index_path(self) -> str:
        return os.path.join(self.dir, _INDEX)

    def _load_index(self) -> None:
        """Replace in-memory records with the on-disk index (source of
        truth for disk-backed stores).

        A corrupt or truncated ``index.json`` (torn write, disk fault) must
        not brick store construction: the bad file is quarantined to
        ``index.json.corrupt-<n>`` with a warning and the store starts
        empty — the content-addressed blobs remain on disk, so identical
        re-materializations are still write-once and quarantined evidence
        survives for post-mortems."""
        path = self._index_path()
        if not os.path.exists(path):
            return
        try:
            with open(path) as f:
                data = json.load(f)
            records = {(r["asset"], r["partition"]): r
                       for r in data.get("records", [])}
        except (json.JSONDecodeError, UnicodeDecodeError, KeyError,
                TypeError, OSError) as e:
            moved = _quarantine(path)
            warnings.warn(
                f"materialization index {path} is corrupt ({e!r}); "
                f"quarantined to {moved or '<unmovable>'} and starting from "
                f"the blobs that remain", StoreCorruption, stacklevel=2)
            with self._lock:
                self._mem = {}
                self._index_mtime = time.time()
            return
        with self._lock:
            self._mem = records
            self._index_mtime = os.path.getmtime(path)

    def reload(self) -> None:
        """Re-read the index from disk (cross-process refresh)."""
        if self.dir:
            self._load_index()

    def _persist_locked(self) -> None:
        """Atomic index rewrite; caller holds ``self._lock``."""
        if not self.dir:
            return
        records = [{k: v for k, v in rec.items() if k != "value"}
                   for rec in self._mem.values()]
        path = self._index_path()
        _durable_write(path, json.dumps(
            {"version": 2, "records": records}, indent=1,
            sort_keys=True).encode())
        self._index_mtime = os.path.getmtime(path)

    def _maybe_refresh(self, key: TaskKey) -> None:
        """On a record miss, pick up an index another process rewrote since
        our last load (mtime-gated so hot loops stay cheap)."""
        if not self.dir or key in self._mem:
            return
        path = self._index_path()
        try:
            mtime = os.path.getmtime(path)
        except OSError:
            return
        if mtime > self._index_mtime:
            self._load_index()

    # ------------------------------------------------------------------ api
    def put(self, asset: str, partition: str, value: Any, fingerprint: str,
            meta: dict | None = None, code_version: str = "",
            upstream: dict[str, str] | None = None) -> dict:
        blob, data_hash = self.data_fingerprint(value)
        rec = {
            "asset": asset, "partition": partition,
            "fingerprint": fingerprint, "data_hash": data_hash,
            "code_version": code_version,
            "upstream": dict(sorted((upstream or {}).items())),
            "time": time.time(), "meta": meta or {},
        }
        if self.dir:
            rel = os.path.join(_BLOBS, f"{data_hash}.pkl")
            path = os.path.join(self.dir, rel)
            if not os.path.exists(path):  # content-addressed: write once
                _durable_write(path, blob)
            rec["path"] = rel
        else:
            rec["value"] = value
        with self._lock:
            self._mem[(asset, partition)] = rec
            self._persist_locked()
        return rec

    def get(self, asset: str, partition: str) -> Any:
        """Load a materialized value, verifying disk bytes against the
        record's ``data_hash`` first: a corrupted or truncated blob is
        quarantined and its record dropped (demoted to never-materialized),
        so callers see a clean ``KeyError`` instead of a raw pickle error —
        or worse, silently wrong data."""
        rec = self.record(asset, partition)
        if rec is None:
            raise KeyError(f"no materialization for {asset}[{partition}]")
        if "value" in rec:
            return rec["value"]
        blob = self._read_verified(asset, partition, rec)
        if blob is None:
            raise KeyError(f"no materialization for {asset}[{partition}] "
                           f"(blob failed integrity check; quarantined)")
        return pickle.loads(blob)

    def _read_verified(self, asset: str, partition: str,
                       rec: dict) -> bytes | None:
        """Blob bytes iff they hash to the record's ``data_hash``; on any
        mismatch/IO error the blob is quarantined and the record dropped."""
        path = os.path.join(self.dir, rec["path"])
        try:
            with open(path, "rb") as f:
                blob = f.read()
        except OSError:
            blob = None
        if blob is not None and \
                _short(hashlib.sha1(blob)) == rec.get("data_hash"):
            return blob
        moved = _quarantine(path) if blob is not None else None
        warnings.warn(
            f"blob for {asset}[{partition}] failed integrity check "
            f"(want data_hash {rec.get('data_hash')}); "
            f"{'quarantined to ' + moved if moved else 'unreadable'} — "
            f"record demoted to never-materialized", StoreCorruption,
            stacklevel=3)
        bad_path = rec["path"]
        with self._lock:
            doomed = [k for k, r in self._mem.items()
                      if r.get("path") == bad_path]
            for k in doomed:
                del self._mem[k]
            if doomed:
                self._persist_locked()
        return None

    def verify(self, asset: str, partition: str) -> bool:
        """True iff a record exists *and* its blob bytes match
        ``data_hash``.  Corrupt blobs are quarantined and their records
        dropped as a side effect — ``resume`` sweeps this over a run's
        cone so crash-corrupted outputs re-run instead of poisoning
        downstream tasks."""
        rec = self.record(asset, partition)
        if rec is None:
            return False
        if "value" in rec or not self.dir or "path" not in rec:
            return True
        return self._read_verified(asset, partition, rec) is not None

    def record(self, asset: str, partition: str) -> dict | None:
        key = (asset, partition)
        self._maybe_refresh(key)
        with self._lock:
            return self._mem.get(key)

    def data_hash(self, asset: str, partition: str) -> str | None:
        rec = self.record(asset, partition)
        return rec.get("data_hash") if rec else None

    def is_fresh(self, asset: str, partition: str, fingerprint: str) -> bool:
        rec = self.record(asset, partition)
        return rec is not None and rec["fingerprint"] == fingerprint

    def invalidate(self, asset: str | None = None,
                   partition: str | None = None) -> int:
        """Drop matching records from the index (blobs stay: they are
        content-addressed and may back other records).  ``None`` matches
        everything on that axis — the ``--force``/backfill escape hatch."""
        with self._lock:
            doomed = [k for k in self._mem
                      if (asset is None or k[0] == asset)
                      and (partition is None or k[1] == partition)]
            for k in doomed:
                del self._mem[k]
            if doomed:
                self._persist_locked()
        return len(doomed)

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)

    def __bool__(self) -> bool:
        # an empty store is still a store: never let ``store or default``
        # silently swap in a fresh one because ``len() == 0``
        return True


def resolve_staleness(graph: "AssetGraph", store: MaterializationStore,
                      targets: list[str] | None = None,
                      force: bool = False) -> dict[TaskKey, Staleness]:
    """Label every (asset, partition) task in the target cone fresh/stale.

    Walks the task DAG in topological order: a task is fresh iff every
    upstream task is fresh, every upstream record exists, and the stored
    fingerprint matches hash(current code version, partition, upstream data
    hashes).  Staleness poisons downstream pessimistically — the launch-time
    check in the coordinator still grants early cutoff when a re-run
    upstream reproduces identical data."""
    from repro.core.schedule import task_dag

    keys, preds = task_dag(graph, targets)
    out: dict[TaskKey, Staleness] = {}
    cv: dict[str, str] = {}
    for tk in keys:
        name, part = tk
        if force:
            out[tk] = Staleness(False, "forced")
            continue
        stale_up = next((p for p in preds[tk] if not out[p].fresh), None)
        if stale_up is not None:
            out[tk] = Staleness(
                False, f"upstream-stale:{stale_up[0]}[{stale_up[1]}]")
            continue
        upstream: dict[str, str] = {}
        missing: TaskKey | None = None
        for (d, k) in preds[tk]:
            h = store.data_hash(d, k)
            if h is None:  # no record (or a pre-content-addressing one):
                missing = (d, k)  # never fresh — no "?" placeholder hashes
                break
            upstream[f"{d}[{k}]"] = h
        if missing is not None:
            out[tk] = Staleness(
                False, f"missing-upstream:{missing[0]}[{missing[1]}]")
            continue
        cver = cv.get(name)
        if cver is None:
            cver = cv[name] = code_version(graph[name])
        fp = MaterializationStore.fingerprint(cver, part, upstream)
        rec = store.record(name, part)
        if rec is None:
            out[tk] = Staleness(False, "never-materialized", fp)
        elif rec["fingerprint"] == fp:
            out[tk] = Staleness(True, "fresh", fp)
        elif rec.get("code_version") != cver:
            out[tk] = Staleness(False, "code-changed", fp)
        elif rec.get("upstream") != upstream:
            out[tk] = Staleness(False, "upstream-data-changed", fp)
        else:
            out[tk] = Staleness(False, "invalidated", fp)
    return out
