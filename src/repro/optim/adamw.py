"""AdamW with warmup+cosine schedule and global-norm clipping.

Pure-functional; optimizer moments inherit the parameter shardings, which —
because every weight is FSDP-sharded over the data axis (DESIGN.md §5) —
gives ZeRO-style partitioned optimizer state with no extra machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def cosine_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(1, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.decay_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


class AdamW:
    def __init__(self, cfg: OptConfig):
        self.cfg = cfg

    def init(self, params: Any) -> dict:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads: Any, state: dict, params: Any) -> tuple[Any, dict, dict]:
        cfg = self.cfg
        step = state["step"] + 1
        lr = cosine_schedule(cfg, step)

        gnorm = global_norm(grads)
        clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)

        b1, b2 = cfg.b1, cfg.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, mm, vv):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + cfg.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        stats = {"lr": lr, "grad_norm": gnorm,
                 "param_norm": global_norm(new_params)}
        return new_params, {"m": m, "v": v, "step": step}, stats
