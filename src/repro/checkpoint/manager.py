"""Fault-tolerant checkpointing: atomic sharded save, async writer, integrity
manifest, latest-valid discovery for auto-resume after preemption.

Layout:  <dir>/step_0000100/
            manifest.json   (tree paths, shapes, dtypes, checksums, metadata)
            arrays.npz      (this process's addressable shards)
            COMMITTED       (written last -> atomicity marker)

On a multi-host pod each process writes its addressable shards under
``proc_<i>``; this container is single-process, so there is exactly one shard
set.  Restore re-shards onto whatever mesh is active (arrays are fed through
``jax.device_put`` with the target sharding).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Callable

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p: Any) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any, metadata: dict | None = None) -> None:
        # materialize on host before handing to the writer thread
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()  # one in-flight write at a time
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, metadata or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_tree, metadata or {})

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree: Any, metadata: dict) -> None:
        try:
            final = self._step_dir(step)
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            flat = _flatten(host_tree)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            manifest = {
                "step": step,
                "time": time.time(),
                "metadata": metadata,
                "arrays": {
                    k: {
                        "shape": list(v.shape),
                        "dtype": str(v.dtype),
                        "sha1_16": hashlib.sha1(
                            np.ascontiguousarray(v).tobytes()[:65536]).hexdigest(),
                    }
                    for k, v in flat.items()
                },
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()
        except Exception as e:  # surfaced on next wait()/save()
            self._error = e

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "COMMITTED")):
                    steps.append(int(name[5:]))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                sharding_fn: Callable[[str], Any] | None = None) -> Any:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``sharding_fn(key)`` may supply a target
        sharding per leaf for resharded restore onto a live mesh."""
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, proto in paths:
            key = "/".join(_path_str(p) for p in path)
            if key not in manifest["arrays"]:
                raise KeyError(f"checkpoint missing array {key!r}")
            arr = data[key]
            expect = tuple(getattr(proto, "shape", arr.shape))
            if tuple(arr.shape) != expect:
                raise ValueError(f"{key}: shape {arr.shape} != {expect}")
            if sharding_fn is not None:
                leaves.append(jax.device_put(arr, sharding_fn(key)))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, like: Any, sharding_fn=None) -> tuple[int, Any] | None:
        step = self.latest_step()
        if step is None:
            return None
        return step, self.restore(step, like, sharding_fn)

    def metadata(self, step: int) -> dict:
        with open(os.path.join(self._step_dir(step), "manifest.json")) as f:
            return json.load(f)["metadata"]

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")
