"""Cost-effective multi-platform big-data orchestration (paper repro).

Kept intentionally import-light: subpackages (``repro.core``, ``repro.models``,
...) pull in jax lazily so orchestration-only consumers stay fast.
"""

__version__ = "0.2.0"
