"""Shared layers: param plumbing, norms, rotary embeddings, MLPs.

Parameters are created as ``Param(value, axes)`` where ``axes`` names the
logical sharding axis of every dim (see distributed/sharding.py).  ``split``
separates the value tree from the axes tree; model code then works with plain
array pytrees, and the axes tree drives NamedSharding construction in the
launcher.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class Param:
    value: Any  # array when initializing, ShapeDtypeStruct when abstract
    axes: tuple[str | None, ...]

    def __post_init__(self) -> None:
        assert len(self.axes) == len(self.value.shape), (self.axes, self.value.shape)


def is_param(x: Any) -> bool:
    return isinstance(x, Param)


def split(tree: Any) -> tuple[Any, Any]:
    """(values, axes) from a tree whose leaves are Param."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: tuple(p.axes), tree, is_leaf=is_param)
    return values, axes


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, shape: tuple[int, ...], in_dims: int, dtype: str) -> jax.Array:
    """Truncated-normal fan-in init (LeCun-style), robust across widths."""
    fan_in = max(1, int(np.prod(shape[:in_dims])))
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, shape: tuple[int, ...], dtype: str) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, d: int) -> dict:
    p = {"scale": Param(jnp.zeros((d,), cfg.param_dtype) if cfg.gemma_norm
                        else jnp.ones((d,), cfg.param_dtype), (None,))}
    if cfg.norm_type == "layernorm":
        p["bias"] = Param(jnp.zeros((d,), cfg.param_dtype), (None,))
    return p


def apply_norm(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        x_hat = (x - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = x_hat * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        x_hat = x * jax.lax.rsqrt(ms + cfg.norm_eps)
        scale = p["scale"].astype(jnp.float32)
        if cfg.gemma_norm:
            scale = 1.0 + scale
        out = x_hat * scale
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Positions: RoPE / M-RoPE / sinusoidal
# ---------------------------------------------------------------------------


def rope_freqs(cfg: ModelConfig, rot_dim: int) -> jax.Array:
    """Inverse frequencies for the rotated sub-dimension (pairs = rot_dim/2)."""
    exponent = jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim
    return 1.0 / (cfg.rope_theta ** exponent)  # (rot_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig,
               rot_dim: int | None = None) -> jax.Array:
    """Rotary embedding, split-half (NeoX) convention.

    x: (..., seq, heads, head_dim); positions: (batch, seq) int32 or
    (3, batch, seq) for M-RoPE (temporal/height/width coordinates).
    """
    head_dim = x.shape[-1]
    rot = rot_dim if rot_dim is not None else int(head_dim * cfg.rope_fraction)
    rot = min(rot, head_dim)
    inv_freq = rope_freqs(cfg, rot)  # (rot/2,)

    if cfg.pos_type == "mrope":
        assert positions.ndim == 3, "mrope needs (3, batch, seq) positions"
        sections = cfg.mrope_sections  # in freq pairs, sums to rot/2
        assert sum(sections) == rot // 2, (sections, rot)
        # angle per pair selected from the section's coordinate stream
        angles = []
        start = 0
        for comp, sec in enumerate(sections):
            f = inv_freq[start : start + sec]  # (sec,)
            pos = positions[comp].astype(jnp.float32)  # (b, s)
            angles.append(pos[..., None] * f)  # (b, s, sec)
            start += sec
        angle = jnp.concatenate(angles, axis=-1)  # (b, s, rot/2)
    else:
        angle = positions.astype(jnp.float32)[..., None] * inv_freq  # (b, s, rot/2)

    sin = jnp.sin(angle)[..., None, :]  # (b, s, 1, rot/2)
    cos = jnp.cos(angle)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., : rot // 2], x_rot[..., rot // 2 :]
    out1 = (x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin)
    out2 = (x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin)
    out = jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype)], axis=-1)
    if x_pass.shape[-1]:
        out = jnp.concatenate([out, x_pass], axis=-1)
    return out


def sinusoidal_positions(seq_len: int, d_model: int, dtype=jnp.float32) -> jax.Array:
    """Standard transformer sinusoids (whisper encoder positions)."""
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * dim / d_model)
    table = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(table, dtype=dtype)


# ---------------------------------------------------------------------------
# MLP (dense / GLU)
# ---------------------------------------------------------------------------


def init_mlp(key: jax.Array, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.param_dtype
    ks = jax.random.split(key, 3)
    p = {
        "w_up": Param(dense_init(ks[0], (d, ff), 1, dt), ("embed_fsdp", "mlp")),
        "w_down": Param(dense_init(ks[1], (ff, d), 1, dt), ("mlp", "embed_fsdp")),
    }
    if cfg.mlp_type == "glu":
        p["w_gate"] = Param(dense_init(ks[2], (d, ff), 1, dt), ("embed_fsdp", "mlp"))
    return p


def _act(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def apply_mlp(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    cdt = cfg.compute_dtype
    h = x @ p["w_up"].astype(cdt)
    if cfg.mlp_type == "glu":
        g = x @ p["w_gate"].astype(cdt)
        h = _act(cfg, g) * h
    else:
        h = _act(cfg, h)
    return h @ p["w_down"].astype(cdt)
