"""Mixture-of-Experts: top-k router + capacity dispatch + expert parallelism.

Three execution paths with identical math (parity-tested):

* ``_moe_dense``  — per-expert einsum over *all* tokens; used on a single
  device (unit tests) and as the small-T GSPMD path for decode shapes, where
  tokens are few (<= _SMALL_T) and a capacity all-to-all would be all overhead.
  With a mesh active, experts stay sharded over the model axis and XLA inserts
  one psum for the combine.
* ``_moe_shard_map`` — the production train/prefill path: GShard-style
  capacity buffers, explicit ``all_to_all`` over the model ("expert") axis,
  FSDP all-gather of expert weights over the data axis, scatter-dispatch /
  gather-combine.  Tokens over (pod, data) x seq over model.

Router: softmax -> top-k -> renormalised gates; standard load-balancing aux
loss (Switch/GShard).  Over-capacity tokens are dropped (residual passes
through), matching GShard semantics.
"""
from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain, current_mesh_info, shard_map_specs
from repro.models.layers import Param, dense_init

try:  # jax >= 0.6 moved shard_map to the top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from jax.sharding import PartitionSpec as P

# jax >= 0.6 renamed check_rep -> check_vma; disable either way (the dispatch
# body's psum_scatter/all_gather pattern defeats the replication checker)
_SM_NOCHECK = (
    {"check_vma": False}
    if "check_vma" in inspect.signature(_shard_map).parameters
    else {"check_rep": False})

_SMALL_T = 4096  # global token threshold below which dense path wins


def init_moe(key: jax.Array, cfg: ModelConfig) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    dt = cfg.param_dtype
    ks = jax.random.split(key, 5)
    p = {
        "router": Param(dense_init(ks[0], (d, e), 1, dt), ("embed_fsdp", None)),
        "w_gate": Param(dense_init(ks[1], (e, d, ff), 2, dt),
                        ("experts", "embed_fsdp", None)),
        "w_up": Param(dense_init(ks[2], (e, d, ff), 2, dt),
                      ("experts", "embed_fsdp", None)),
        "w_down": Param(dense_init(ks[3], (e, ff, d), 2, dt),
                        ("experts", "expert_ff_fsdp", None)),
    }
    return p


def _act(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True) if cfg.act == "gelu" else jax.nn.silu(x)


def _route(router_w: jax.Array, x2d: jax.Array, cfg: ModelConfig):
    """probs/top-k/aux from router logits.  x2d: (T, d)."""
    logits = (x2d @ router_w.astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)  # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load balance loss: E * sum_e f_e * p_e
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)  # (E,) mean router prob
    assign = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)  # top-1 fraction
    fe = jnp.mean(assign, axis=0)
    aux = e * jnp.sum(fe * me)
    return gates, idx, aux


# ---------------------------------------------------------------------------
# dense / small-T path
# ---------------------------------------------------------------------------


def _moe_dense(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    cdt = cfg.compute_dtype
    B, S, d = x.shape
    x2d = x.reshape(-1, d)
    gates, idx, aux = _route(p["router"], x2d, cfg)
    # all-experts compute (T small): h (T, E, ff) with E sharded over model
    h = jnp.einsum("td,edf->tef", x2d, p["w_gate"].astype(cdt))
    u = jnp.einsum("td,edf->tef", x2d, p["w_up"].astype(cdt))
    h = _act(cfg, h) * u
    h = constrain(h, None, "experts", None)
    y_e = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(cdt))
    y_e = constrain(y_e, None, "experts", None)
    # combine: sum_k gate_k * y_e[t, idx_k]
    sel = jax.nn.one_hot(idx, cfg.n_experts, dtype=cdt)  # (T, K, E)
    w_comb = jnp.einsum("tk,tke->te", gates.astype(cdt), sel)  # (T, E)
    y = jnp.einsum("te,ted->td", w_comb, y_e)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# shard_map capacity-dispatch path
# ---------------------------------------------------------------------------


def _capacity(tokens_local: int, cfg: ModelConfig) -> int:
    c = int(tokens_local * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, ((c + 7) // 8) * 8)


def _dispatch_compute_combine(
    x_l: jax.Array,  # (b_l, s_l, d) local tokens
    router_l: jax.Array,  # (d_shard, E)
    wg_l: jax.Array,  # (E_l, d_shard, ff)
    wu_l: jax.Array,
    wd_l: jax.Array,  # (E_l, ff_shard, d)
    *,
    cfg: ModelConfig,
    data_axis: str | None,
    model_axis: str,
    all_axes: tuple,
) -> tuple[jax.Array, jax.Array]:
    cdt = cfg.compute_dtype
    b_l, s_l, d = x_l.shape
    E = cfg.n_experts

    # FSDP gathers (weights stored sharded over the data axis)
    if data_axis is not None:
        router_w = jax.lax.all_gather(router_l, data_axis, axis=0, tiled=True)
        w_gate = jax.lax.all_gather(wg_l, data_axis, axis=1, tiled=True)
        w_up = jax.lax.all_gather(wu_l, data_axis, axis=1, tiled=True)
        w_down = jax.lax.all_gather(wd_l, data_axis, axis=1, tiled=True)
    else:
        router_w, w_gate, w_up, w_down = router_l, wg_l, wu_l, wd_l

    x2d = x_l.reshape(-1, d)  # (T_l, d)
    t_l = x2d.shape[0]
    gates, idx, aux = _route(router_w, x2d, cfg)
    cap = _capacity(t_l, cfg)

    # position of each (token, slot) within its expert buffer
    flat_e = idx.reshape(-1)  # (T_l*K,) row-major (t, k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T_l*K, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # exclusive count
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # (T_l*K,)
    keep = pos < cap
    pos_c = jnp.minimum(pos, cap - 1)

    x_rep = jnp.repeat(x2d, cfg.top_k, axis=0)  # (T_l*K, d)
    val = jnp.where(keep[:, None], x_rep.astype(cdt), 0)
    buf = jnp.zeros((E, cap, d), cdt).at[flat_e, pos_c].add(val)

    # expert-parallel exchange: (E, cap, d) -> (E_l, cap * ep, d)
    buf = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=1,
                             tiled=True)
    h = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(cdt))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(cdt))
    y = jnp.einsum("ecf,efd->ecd", _act(cfg, h) * u, w_down.astype(cdt))
    y = jax.lax.all_to_all(y, model_axis, split_axis=1, concat_axis=0,
                           tiled=True)  # back to (E, cap, d)

    # combine: gather back per (token, slot), weight by gates, drop overflow
    picked = y[flat_e, pos_c]  # (T_l*K, d)
    picked = jnp.where(keep[:, None], picked, 0)
    out = (picked.reshape(t_l, cfg.top_k, d)
           * gates.astype(cdt)[..., None]).sum(axis=1)
    aux = jax.lax.pmean(aux, all_axes)
    return out.reshape(b_l, s_l, d), aux


def _moe_shard_map(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    info = current_mesh_info()
    data_axes, model_axis = shard_map_specs(info)
    mesh = info.mesh
    data_axis = "data" if "data" in mesh.axis_names else None
    batch_spec = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bs = batch_spec[0] if len(batch_spec) == 1 else batch_spec

    fn = functools.partial(
        _dispatch_compute_combine,
        cfg=cfg,
        data_axis=data_axis,
        model_axis=model_axis,
        all_axes=tuple(mesh.axis_names),
    )
    out, aux = _shard_map(
        fn,
        mesh=mesh,
        in_specs=(
            P(bs, "model", None),  # x: batch over DP axes, seq over model
            P("data", None),  # router
            P("model", "data", None),  # w_gate
            P("model", "data", None),  # w_up
            P("model", "data", None),  # w_down
        ),
        out_specs=(P(bs, "model", None), P()),
        **_SM_NOCHECK,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux


def _shard_map_viable(cfg: ModelConfig, x: jax.Array) -> bool:
    info = current_mesh_info()
    if info is None or "model" not in info.mesh.axis_names:
        return False
    B, S, _ = x.shape
    if B * S <= _SMALL_T:
        return False
    mdl = info.axis_size("model")
    dp = info.axis_size("data") * info.axis_size("pod")
    return (B % dp == 0 and S % mdl == 0 and cfg.n_experts % mdl == 0
            and cfg.d_model % info.axis_size("data") == 0)


def apply_moe(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    if _shard_map_viable(cfg, x):
        return _moe_shard_map(p, cfg, x)
    return _moe_dense(p, cfg, x)
