"""Attention: GQA/MQA/MHA, causal + bidirectional + sliding-window, cross.

The reference computation is *q-chunked* (streaming) so the XLA-fused CPU/TPU
fallback path never materialises a full (Sq x Skv) score tensor — the Pallas
flash kernel (kernels/flash_attention.py) is the TPU-optimized equivalent and
is validated against this math.  Sliding-window layers additionally slice the
KV band per q-chunk, so SWA prefill is O(S * window), not O(S^2).

KV caches carry an explicit per-slot ``pos`` array (-1 = empty), which makes
full caches, ring buffers (SWA) and cross-attention caches uniform: masks are
always computed from true token positions.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain, current_mesh_info
from repro.models.layers import Param, apply_rope, dense_init

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Context threading through the model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ModelCtx:
    mode: str  # train | prefill | chunk_prefill | decode | encode
    positions: jax.Array  # (B, S) int32; or (3, B, S) for mrope
    cache_pos: jax.Array | None = None  # (B,) int32 write position (decode)
    enc_out: jax.Array | None = None  # (B, S_enc, d) encoder output
    enc_positions: jax.Array | None = None  # (B, S_enc)
    causal: bool = True
    #: (B, max_pages) int32 block table for paged KV pools (decode only);
    #: entries == n_pages mark unallocated logical pages.  Carried on the ctx
    #: (not in the cache pytree) so scanned segments see it as a closure
    #: capture instead of a scanned leaf.
    table: jax.Array | None = None

    @property
    def pos2d(self) -> jax.Array:
        """(B, S) positions regardless of mrope (temporal component)."""
        return self.positions[0] if self.positions.ndim == 3 else self.positions


def kv_heads_shardable(n_kv_heads: int) -> bool:
    info = current_mesh_info()
    if info is None:
        return True
    return n_kv_heads % max(1, info.axis_size("model")) == 0


def cache_axes(n_kv_heads: int) -> tuple:
    """(B, S, H_kv, D) cache axes; shard heads if divisible, else the seq dim
    (SP-decode: long KV caches spread over the model axis)."""
    if kv_heads_shardable(n_kv_heads):
        return ("batch", None, "kv_heads", None)
    return ("batch", "kv_seq", None, None)


# ---------------------------------------------------------------------------
# Streaming attention core
# ---------------------------------------------------------------------------


def _pick_chunk(sq: int) -> int:
    if sq <= 1024:
        return sq
    c = max(128, min(1024, sq // 32))
    while sq % c:
        c //= 2
    return max(c, 1)


def attention_core(
    q: jax.Array,  # (B, Sq, Hq, Dk)
    k: jax.Array,  # (B, Skv, Hkv, Dk)
    v: jax.Array,  # (B, Skv, Hkv, Dv)
    pos_q: jax.Array,  # (B, Sq) int32
    pos_k: jax.Array,  # (B, Skv) int32, -1 marks empty slots
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
) -> jax.Array:
    B, Sq, Hq, Dk = q.shape
    _, Skv, Hkv, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = scale if scale is not None else Dk ** -0.5

    # Shard-aligned path for archs whose head count doesn't divide the model
    # axis (gemma 8H, minicpm3 40H on TP=16): q is *sequence*-sharded there,
    # so a q-chunk loop over the global sequence would re-gather every chunk
    # across devices each iteration (measured 9 GiB x 576 trips on the
    # baseline — EXPERIMENTS.md §Perf iteration 1).  Fold the sharded dim out
    # of the loop: reshape S -> (tp, L) keeping tp sharded, then loop over
    # L-chunks so each iteration is device-local.  Masks are computed from
    # explicit positions, so the non-contiguous row blocks stay exact.
    tp_out = _shard_aligned_attention(q.reshape(B, Sq, Hkv, G, Dk), pos_q,
                                      k, v, pos_k, causal=causal,
                                      window=window, scale=scale)
    if tp_out is not None:
        return tp_out

    # GQA: expand K/V to the q-head count instead of reshaping q into
    # (Hkv, G) groups — reshaping a TP-sharded 64-head dim into (8, 8) can't
    # stay sharded, so GSPMD replicated every attention tensor per q-chunk
    # (measured 160 GiB x 2560 trips on qwen2-vl-72b train — §Perf iteration
    # 4).  The repeat is sharding-preserving and FLOP-neutral; each device
    # ends up holding exactly the kv heads its q heads read.
    if G > 1 and Sq > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        if kv_heads_shardable(Hq):
            k = constrain(k, "batch", None, "heads", None)
            v = constrain(v, "batch", None, "heads", None)
        return _attention_expanded(q, k, v, pos_q, pos_k, causal=causal,
                                   window=window, scale=scale)
    if G == 1 and Sq > 1:
        return _attention_expanded(q, k, v, pos_q, pos_k, causal=causal,
                                   window=window, scale=scale)

    # decode (Sq == 1): grouped einsum against the (possibly seq-sharded)
    # cache — no repeat, so cache reads stay 1/G of the expanded cost.
    qg = q.reshape(B, Sq, Hkv, G, Dk)

    def block(q_blk: jax.Array, pq: jax.Array, k_: jax.Array, v_: jax.Array,
              pk: jax.Array) -> jax.Array:
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_,
                       preferred_element_type=jnp.float32) * scale
        mask = (pk >= 0)[:, None, None, None, :]
        if causal:
            mask &= pk[:, None, None, None, :] <= pq[:, None, None, :, None]
        if window > 0:
            mask &= (pq[:, None, None, :, None] - pk[:, None, None, None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_.dtype), v_)
        return o.reshape(B, -1, Hq, Dv)

    return block(qg, pos_q, k, v, pos_k)


def _attention_expanded(q, k, v, pos_q, pos_k, *, causal, window, scale):
    """Plain q-chunked attention with per-head K/V (no grouping)."""
    B, Sq, Hq, Dk = q.shape
    Skv = k.shape[1]
    Dv = v.shape[-1]

    def block(q_blk: jax.Array, pq: jax.Array, k_: jax.Array, v_: jax.Array,
              pk: jax.Array) -> jax.Array:
        s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_,
                       preferred_element_type=jnp.float32) * scale
        mask = (pk >= 0)[:, None, None, :]
        if causal:
            mask &= pk[:, None, None, :] <= pq[:, None, :, None]
        if window > 0:
            mask &= (pq[:, None, :, None] - pk[:, None, None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v_.dtype), v_)

    chunk = _pick_chunk(Sq)
    if Sq == chunk:
        return block(q, pos_q, k, v, pos_k)

    nc = Sq // chunk
    qc = jnp.moveaxis(q.reshape(B, nc, chunk, Hq, Dk), 1, 0)
    pc = jnp.moveaxis(pos_q.reshape(B, nc, chunk), 1, 0)

    # Banded path: for sliding-window prefill slice the KV band per q-chunk so
    # the work is O(S*window).  Valid because prefill cache slots are
    # position-ordered (pos_k == arange over the computed sequence).
    if window > 0 and Skv > (window + chunk):
        band = _round_up(window + chunk, 128)

        def banded_step(args):
            q_blk, pq, start = args
            lo = jnp.maximum(start + chunk - band, 0)
            k_b = jax.lax.dynamic_slice_in_dim(k, lo, band, axis=1)
            v_b = jax.lax.dynamic_slice_in_dim(v, lo, band, axis=1)
            pk_b = jax.lax.dynamic_slice_in_dim(pos_k, lo, band, axis=1)
            return block(q_blk, pq, k_b, v_b, pk_b)

        starts = jnp.arange(nc, dtype=jnp.int32) * chunk
        out = jax.lax.map(banded_step, (qc, pc, starts))
    else:
        out = jax.lax.map(lambda a: block(a[0], a[1], k, v, pos_k), (qc, pc))
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, Hq, Dv)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


_SCORE_BYTES_BUDGET = 700e6  # per-device f32 score-block budget


def _attn_block_tp(q_blk, pq, k, v, pk, causal, window, scale):
    """q_blk: (B, tp, c, Hkv, G, D) with tp sharded; k/v replicated."""
    B = q_blk.shape[0]
    hq = q_blk.shape[3] * q_blk.shape[4]
    dv = v.shape[-1]
    s = jnp.einsum("btqhgd,bkhd->bhgtqk", q_blk, k,
                   preferred_element_type=jnp.float32) * scale
    mask = (pk >= 0)[:, None, None, None, None, :]
    if causal:
        mask &= pk[:, None, None, None, None, :] <= pq[:, :, :, None][:, None, None]
    if window > 0:
        mask &= (pq[:, :, :, None][:, None, None]
                 - pk[:, None, None, None, None, :]) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgtqk,bkhd->btqhgd", p.astype(v.dtype), v)
    return o.reshape(B, q_blk.shape[1], q_blk.shape[2], hq, dv)


def _shard_aligned_attention(qg, pos_q, k, v, pos_k, *, causal, window,
                             scale):
    """Returns the attention output for the seq-sharded-q regime, or None if
    the plain path applies (single device / heads shardable / tiny seq)."""
    info = current_mesh_info()
    if info is None:
        return None
    tp = info.axis_size("model")
    B, Sq, Hkv, G, Dk = qg.shape
    Skv = k.shape[1]
    Hq, Dv = Hkv * G, v.shape[-1]
    if (tp <= 1 or Sq <= 1 or kv_heads_shardable(Hq) or Sq % tp
            or Sq <= _pick_chunk(Sq)):
        return None
    dp = info.axis_size("data") * info.axis_size("pod")
    b_loc = max(1, B // max(dp, 1))
    ll = Sq // tp
    row_bytes = b_loc * Hq * Skv * 4
    c2 = max(16, int(_SCORE_BYTES_BUDGET // max(row_bytes, 1)))
    c2 = min(c2, ll)
    while ll % c2:
        c2 -= 1
    qs = constrain(qg.reshape(B, tp, ll, Hkv, G, Dk),
                   "batch", "seq_act", None, None, None, None)
    ps = pos_q.reshape(B, tp, ll)
    if c2 == ll:  # one device-local block, no loop
        out = _attn_block_tp(qs, ps, k, v, pos_k, causal, window, scale)
    else:
        nc = ll // c2
        qc = jnp.moveaxis(qs.reshape(B, tp, nc, c2, Hkv, G, Dk), 2, 0)
        pc = jnp.moveaxis(ps.reshape(B, tp, nc, c2), 2, 0)
        out = jax.lax.map(
            lambda a: _attn_block_tp(a[0], a[1], k, v, pos_k, causal,
                                     window, scale), (qc, pc))
        out = jnp.moveaxis(out, 0, 2)  # (B, tp, nc*? c2, H, Dv) blocks
        out = out.reshape(B, tp, ll, Hq, Dv)
    return out.reshape(B, Sq, Hq, Dv)


# ---------------------------------------------------------------------------
# Cache plumbing (full + ring buffers, explicit slot positions)
# ---------------------------------------------------------------------------


def make_kv_cache(batch: int, size: int, n_kv: int, dk: int, dv: int, dtype) -> dict:
    return {
        "k": jnp.zeros((batch, size, n_kv, dk), dtype),
        "v": jnp.zeros((batch, size, n_kv, dv), dtype),
        "pos": jnp.full((batch, size), -1, jnp.int32),
    }


def kv_cache_specs(batch: int, size: int, n_kv: int, dk: int, dv: int, dtype) -> dict:
    ax = cache_axes(n_kv)
    return {
        "k": (jax.ShapeDtypeStruct((batch, size, n_kv, dk), dtype), ax),
        "v": (jax.ShapeDtypeStruct((batch, size, n_kv, dv), dtype), ax),
        "pos": (jax.ShapeDtypeStruct((batch, size), jnp.int32), ("batch", ax[1])),
    }


def prefill_cache(cache: dict, k: jax.Array, v: jax.Array, pos: jax.Array) -> dict:
    """Write a full prefix into a (possibly ring) cache.  For ring caches only
    the last `size` tokens are written (unique slots => deterministic)."""
    size = cache["k"].shape[1]
    S = k.shape[1]
    if S <= size:
        k_w, v_w, p_w = k, v, pos
    else:
        k_w, v_w, p_w = k[:, -size:], v[:, -size:], pos[:, -size:]
    slots = p_w % size  # unique within the window
    b_idx = jnp.arange(k.shape[0])[:, None]
    return {
        "k": cache["k"].at[b_idx, slots].set(k_w.astype(cache["k"].dtype)),
        "v": cache["v"].at[b_idx, slots].set(v_w.astype(cache["v"].dtype)),
        "pos": cache["pos"].at[b_idx, slots].set(p_w),
    }


def append_cache(cache: dict, k_t: jax.Array, v_t: jax.Array, pos: jax.Array) -> dict:
    """Append one token (decode). k_t: (B, 1, H, D); pos: (B,).

    pos < 0 marks an inactive slot (e.g. mid-chunk-prefill in the paged
    engine): its write maps to an out-of-bounds index and is dropped, so
    decoding the shared batch never clobbers a slot being prefilled."""
    size = cache["k"].shape[1]
    slots = jnp.where(pos >= 0, pos % size, size)
    b_idx = jnp.arange(k_t.shape[0])
    return {
        "k": cache["k"].at[b_idx, slots].set(k_t[:, 0].astype(cache["k"].dtype)),
        "v": cache["v"].at[b_idx, slots].set(v_t[:, 0].astype(cache["v"].dtype)),
        "pos": cache["pos"].at[b_idx, slots].set(pos),
    }


# ---------------------------------------------------------------------------
# Paged KV pools (block-table indirection, shared across decode slots)
# ---------------------------------------------------------------------------


def paged_kv_cache_specs(n_pages: int, page_size: int, n_kv: int, dk: int,
                         dv: int, dtype) -> dict:
    """Specs for a page *pool*: no batch dim — physical pages are allocated
    to slots through a block table (see launch/paged_kv.py).  The ``pages``
    logical tag is how gather/scatter code finds the pool dim."""
    ax = ("pages", None, "kv_heads", None)
    return {
        "k": (jax.ShapeDtypeStruct((n_pages, page_size, n_kv, dk), dtype), ax),
        "v": (jax.ShapeDtypeStruct((n_pages, page_size, n_kv, dv), dtype), ax),
        "pos": (jax.ShapeDtypeStruct((n_pages, page_size), jnp.int32),
                ("pages", None)),
    }


def paged_append(cache: dict, k_t: jax.Array, v_t: jax.Array, pos: jax.Array,
                 table: jax.Array) -> dict:
    """Append one token per slot into the page pool (decode).

    k_t: (B, 1, H, D); pos: (B,) absolute positions; table: (B, P).
    Slots with pos < 0 (inactive) and unallocated logical pages resolve to an
    out-of-bounds page index, so their scatter is dropped — a dead slot can
    never corrupt pages that have been recycled to another request."""
    n_pages, ps = cache["pos"].shape
    P = table.shape[1]
    valid = (pos >= 0) & (pos < P * ps)
    lpage = jnp.clip(pos // ps, 0, P - 1)
    page = jnp.take_along_axis(table, lpage[:, None], axis=1)[:, 0]
    page = jnp.where(valid, page, n_pages)  # OOB scatter index -> dropped
    off = pos % ps
    return {
        "k": cache["k"].at[page, off].set(k_t[:, 0].astype(cache["k"].dtype)),
        "v": cache["v"].at[page, off].set(v_t[:, 0].astype(cache["v"].dtype)),
        "pos": cache["pos"].at[page, off].set(pos),
    }


# ---------------------------------------------------------------------------
# Standard (GQA) attention layer
# ---------------------------------------------------------------------------


def init_attention(key: jax.Array, cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    ks = jax.random.split(key, 4)
    kv_ax = "kv_heads"
    return {
        "w_q": Param(dense_init(ks[0], (d, h, hd), 1, dt), ("embed_fsdp", "heads", None)),
        "w_k": Param(dense_init(ks[1], (d, hkv, hd), 1, dt), ("embed_fsdp", kv_ax, None)),
        "w_v": Param(dense_init(ks[2], (d, hkv, hd), 1, dt), ("embed_fsdp", kv_ax, None)),
        "w_o": Param(dense_init(ks[3], (h, hd, d), 2, dt), ("heads", None, "embed_fsdp")),
    }


def apply_attention(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    ctx: ModelCtx,
    cache: dict | None,
    *,
    window: int = 0,
    cross: bool = False,
    paged: bool = False,
) -> tuple[jax.Array, dict | None]:
    cdt = cfg.compute_dtype
    B, S, _ = x.shape
    heads_tp = kv_heads_shardable(cfg.n_heads)

    # Megatron-style SP->TP boundary: un-shard the sequence ONCE (bf16) so
    # the q/k/v projections and attention run TP-local.  Without this, GSPMD
    # implemented the seq->heads output resharding by gathering x in f32 per
    # projection (3x the bytes) — §Perf iteration 5.
    if heads_tp and S > 1:
        x = constrain(x, "batch", None, None)

    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"].astype(cdt))
    q = constrain(q, "batch", None if heads_tp else "seq_act",
                  "heads" if heads_tp else None, None)

    if cross:
        # K/V come from the encoder output; cached once at prefill.
        if cache is not None and ctx.mode == "decode":
            k, v, pos_k = cache["k"], cache["v"], cache["pos"]
            new_cache = cache
        else:
            src = ctx.enc_out
            k = jnp.einsum("bsd,dhk->bshk", src, p["w_k"].astype(cdt))
            v = jnp.einsum("bsd,dhk->bshk", src, p["w_v"].astype(cdt))
            pos_k = ctx.enc_positions
            new_cache = None
            if cache is not None:  # prefill: persist cross K/V
                new_cache = prefill_cache(cache, k, v, pos_k)
        pos_q = ctx.pos2d
        o = attention_core(q, k.astype(cdt), v.astype(cdt), pos_q, pos_k,
                           causal=False, window=0)
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["w_k"].astype(cdt))
        v = jnp.einsum("bsd,dhk->bshk", x, p["w_v"].astype(cdt))
        if cfg.pos_type in ("rope", "mrope"):
            q = apply_rope(q, ctx.positions, cfg)
            k = apply_rope(k, ctx.positions, cfg)
        pos_q = ctx.pos2d
        kv_ax = cache_axes(cfg.n_kv_heads)
        new_cache = None
        if cache is None:  # train / encode: attend within the computed seq
            k_att, v_att, pos_k = k, v, pos_q
            o = attention_core(q, k_att, v_att, pos_q, pos_k,
                               causal=ctx.causal, window=window)
        elif ctx.mode == "decode" and paged:
            # Page-pool cache: scatter the new token through the block table,
            # then attend over the slot's gathered pages (kernels/ops).
            new_cache = paged_append(cache, k, v, ctx.cache_pos, ctx.table)
            from repro.kernels import ops as kops
            o = kops.paged_attention(
                q, new_cache["k"].astype(cdt), new_cache["v"].astype(cdt),
                new_cache["pos"], ctx.table, pos_q, causal=ctx.causal,
                window=window)
        elif ctx.mode == "decode":
            new_cache = append_cache(cache, k, v, ctx.cache_pos)
            k_att = constrain(new_cache["k"], *kv_ax).astype(cdt)
            v_att = constrain(new_cache["v"], *kv_ax).astype(cdt)
            pos_k = new_cache["pos"]
            o = attention_core(q, k_att, v_att, pos_q, pos_k,
                               causal=ctx.causal, window=window)
        elif ctx.mode == "chunk_prefill":
            # Continue a prefix already in the cache: attend over (cache
            # contents ∪ this chunk), then persist the chunk.  Works for full
            # caches and SWA rings alike — masks come from explicit positions,
            # and empty slots carry pos == -1.
            k_att = jnp.concatenate([cache["k"].astype(cdt), k], axis=1)
            v_att = jnp.concatenate([cache["v"].astype(cdt), v], axis=1)
            pos_k = jnp.concatenate([cache["pos"], pos_q], axis=1)
            new_cache = prefill_cache(cache, k, v, pos_q)
            o = attention_core(q, k_att, v_att, pos_q, pos_k,
                               causal=ctx.causal, window=window)
        else:  # prefill: attend over computed seq, persist into cache
            new_cache = prefill_cache(cache, k, v, pos_q)
            k_att, v_att, pos_k = k, v, pos_q
            o = attention_core(q, k_att, v_att, pos_q, pos_k,
                               causal=ctx.causal, window=window)

    o = constrain(o, "batch", None if heads_tp else "seq_act",
                  "heads" if heads_tp else None, None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["w_o"].astype(cdt))
    return constrain(out, "batch", "seq_act", None), new_cache
