"""Recurrent blocks: RG-LRU (Griffin [arXiv:2402.19427]) and RWKV-6 (Finch
[arXiv:2404.05892]).

Both have three numerically-consistent forms (parity-tested):
  * naive per-step scan (the oracle, also the decode path),
  * a parallel form for train/prefill — associative scan for RG-LRU, a
    chunked-parallel form for RWKV-6 (intra-chunk attention-like einsum in
    log-decay space + inter-chunk state carry),
  * the Pallas chunked kernel (kernels/linear_scan.py) targeting TPU.

Feature dims (lru_width / rwkv heads) are elementwise in the recurrence, so
tensor parallelism shards them over the model axis with zero collectives —
the TPU-native answer to "how do SSM layers scale" (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import Param, dense_init

RG_LRU_C = 8.0  # Griffin's fixed gate exponent


# ---------------------------------------------------------------------------
# RG-LRU recurrent block
# ---------------------------------------------------------------------------


def init_rglru(key: jax.Array, cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    dt = cfg.param_dtype
    ks = jax.random.split(key, 7)
    # Lambda init so a = exp(-c*softplus(lam)) ~ U[0.9, 0.999]  (Griffin A.2)
    a0 = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(a0) / RG_LRU_C))
    return {
        "w_y": Param(dense_init(ks[0], (d, w), 1, dt), ("embed_fsdp", "lru_width")),
        "w_x": Param(dense_init(ks[1], (d, w), 1, dt), ("embed_fsdp", "lru_width")),
        "conv_w": Param(jnp.zeros((cfg.conv_width, w), dt), (None, "lru_width")),
        "conv_b": Param(jnp.zeros((w,), dt), ("lru_width",)),
        "w_a": Param(dense_init(ks[2], (w, w), 1, dt), ("lru_width", "lru_width")),
        "b_a": Param(jnp.zeros((w,), dt), ("lru_width",)),
        "w_i": Param(dense_init(ks[3], (w, w), 1, dt), ("lru_width", "lru_width")),
        "b_i": Param(jnp.zeros((w,), dt), ("lru_width",)),
        "lam": Param(lam.astype(jnp.float32), ("lru_width",)),
        "w_o": Param(dense_init(ks[4], (w, d), 1, dt), ("lru_width", "embed_fsdp")),
    }


def make_rglru_state(batch: int, cfg: ModelConfig) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.float32),
    }


def rglru_state_specs(batch: int, cfg: ModelConfig) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": (jax.ShapeDtypeStruct((batch, w), jnp.float32),
              ("batch", "lru_width")),
        "conv": (jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, w), jnp.float32),
                 ("batch", None, "lru_width")),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds (width is tiny)."""
    cw = w.shape[0]
    out = u * w[-1]
    for i in range(1, cw):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, : u.shape[1]]
        out = out + shifted * w[-1 - i]
    return out + b


def _rg_gates(p: dict, cfg: ModelConfig, u: jax.Array):
    f32 = jnp.float32
    r = jax.nn.sigmoid((u @ p["w_a"].astype(u.dtype)).astype(f32)
                       + p["b_a"].astype(f32))
    i = jax.nn.sigmoid((u @ p["w_i"].astype(u.dtype)).astype(f32)
                       + p["b_i"].astype(f32))
    log_a = -RG_LRU_C * jax.nn.softplus(p["lam"].astype(f32)) * r
    mult = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    gated = u.astype(f32) * i * mult
    return log_a, gated


def apply_rglru(p: dict, cfg: ModelConfig, x: jax.Array, state: dict | None,
                mode: str,
                active: jax.Array | None = None) -> tuple[jax.Array, dict | None]:
    cdt = cfg.compute_dtype
    y_gate = jax.nn.gelu(x @ p["w_y"].astype(cdt), approximate=True)
    u_pre = x @ p["w_x"].astype(cdt)
    u_pre = constrain(u_pre, "batch", None, "lru_width")

    if mode == "decode":
        assert state is not None
        conv_cache = state["conv"]  # (B, cw-1, w) holds u_{t-cw+1..t-1}
        w_c = p["conv_w"].astype(jnp.float32)
        u = (u_pre[:, 0].astype(jnp.float32) * w_c[-1]
             + jnp.einsum("bcw,cw->bw", conv_cache, w_c[:-1])
             + p["conv_b"].astype(jnp.float32))
        log_a, gated = _rg_gates(p, cfg, u[:, None, :].astype(cdt))
        a = jnp.exp(log_a[:, 0])
        h = a * state["h"] + gated[:, 0]
        conv_new = jnp.concatenate(
            [conv_cache[:, 1:], u_pre.astype(jnp.float32)], axis=1)
        if active is not None:  # inactive slots keep their state verbatim
            h = jnp.where(active[:, None], h, state["h"])
            conv_new = jnp.where(active[:, None, None], conv_new, conv_cache)
        new_state = {"h": h, "conv": conv_new}
        out = (y_gate * h[:, None, :].astype(cdt)) @ p["w_o"].astype(cdt)
        return constrain(out, "batch", None, "embed_fsdp"), new_state

    u_hist = u_pre.astype(jnp.float32)
    if mode == "chunk_prefill":
        assert state is not None
        # Carry the causal-conv window across chunks: prepend the cached
        # u-history, convolve, then drop the history rows.  A fresh state is
        # all-zeros, which matches _causal_conv's implicit zero padding, so
        # the first chunk is bit-identical to an uncarried prefill.
        u_hist = jnp.concatenate([state["conv"], u_hist], axis=1)
        u = _causal_conv(u_hist, p["conv_w"].astype(jnp.float32),
                         p["conv_b"].astype(jnp.float32))
        u = u[:, cfg.conv_width - 1:].astype(cdt)
    else:
        u = _causal_conv(u_hist, p["conv_w"].astype(jnp.float32),
                         p["conv_b"].astype(jnp.float32)).astype(cdt)
    log_a, gated = _rg_gates(p, cfg, u)
    a = jnp.exp(log_a)

    def binop(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    a_cum, h = jax.lax.associative_scan(binop, (a, gated), axis=1)
    if state is not None and mode in ("prefill_continue", "chunk_prefill"):
        h = h + a_cum * state["h"][:, None, :]

    new_state = None
    if mode in ("prefill", "chunk_prefill"):
        new_state = {
            "h": h[:, -1],
            "conv": u_hist[:, -(cfg.conv_width - 1):],
        }
    out = (y_gate * h.astype(cdt)) @ p["w_o"].astype(cdt)
    return constrain(out, "batch", None, "embed_fsdp"), new_state


# ---------------------------------------------------------------------------
# RWKV-6 (Finch)
# ---------------------------------------------------------------------------

_N_MIX = 5  # w, k, v, r, g ddlerp streams


def init_rwkv_time_mix(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_size
    n = cfg.rwkv_head_size
    lm, ld = cfg.rwkv_mix_lora, cfg.rwkv_decay_lora
    dt = cfg.param_dtype
    ks = jax.random.split(key, 10)
    ramp = jnp.linspace(0.0, 1.0, d, dtype=jnp.float32)
    # decay base: -6 .. -1 ramp => per-channel half-lives spanning decades
    w0 = -6.0 + 5.0 * ramp ** 1.3
    return {
        "mu_x": Param(0.5 * jnp.ones((d,), dt), (None,)),
        "mu": Param(0.5 * jnp.ones((_N_MIX, d), dt), (None, None)),
        "mix_A": Param(dense_init(ks[0], (d, _N_MIX, lm), 1, dt),
                       ("embed_fsdp", None, "lora")),
        "mix_B": Param(dense_init(ks[1], (_N_MIX, lm, d), 2, dt),
                       (None, "lora", None)),
        "w0": Param(w0.astype(jnp.float32), (None,)),
        "decay_A": Param(dense_init(ks[2], (d, ld), 1, dt), ("embed_fsdp", "lora")),
        "decay_B": Param(dense_init(ks[3], (ld, d), 1, dt), ("lora", None)),
        "u": Param((jax.random.normal(ks[4], (h, n), jnp.float32) * 0.1).astype(dt),
                   ("rwkv_heads", None)),
        "w_r": Param(dense_init(ks[5], (d, d), 1, dt), ("embed_fsdp", "mlp")),
        "w_k": Param(dense_init(ks[6], (d, d), 1, dt), ("embed_fsdp", "mlp")),
        "w_v": Param(dense_init(ks[7], (d, d), 1, dt), ("embed_fsdp", "mlp")),
        "w_g": Param(dense_init(ks[8], (d, d), 1, dt), ("embed_fsdp", "mlp")),
        "ln_w": Param(jnp.ones((d,), dt), (None,)),
        "ln_b": Param(jnp.zeros((d,), dt), (None,)),
        "w_o": Param(dense_init(ks[9], (d, d), 1, dt), ("mlp", "embed_fsdp")),
    }


def init_rwkv_channel_mix(key: jax.Array, cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    dt = cfg.param_dtype
    ks = jax.random.split(key, 3)
    return {
        "mu_k": Param(0.5 * jnp.ones((d,), dt), (None,)),
        "mu_r": Param(0.5 * jnp.ones((d,), dt), (None,)),
        "w_k": Param(dense_init(ks[0], (d, ff), 1, dt), ("embed_fsdp", "mlp")),
        "w_v": Param(dense_init(ks[1], (ff, d), 1, dt), ("mlp", "embed_fsdp")),
        "w_r": Param(dense_init(ks[2], (d, d), 1, dt), ("embed_fsdp", "mlp")),
    }


def make_rwkv_state(batch: int, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h, n = d // cfg.rwkv_head_size, cfg.rwkv_head_size
    return {
        "S": jnp.zeros((batch, h, n, n), jnp.float32),
        "x_tm": jnp.zeros((batch, d), jnp.float32),
        "x_cm": jnp.zeros((batch, d), jnp.float32),
    }


def rwkv_state_specs(batch: int, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h, n = d // cfg.rwkv_head_size, cfg.rwkv_head_size
    return {
        "S": (jax.ShapeDtypeStruct((batch, h, n, n), jnp.float32),
              ("batch", "rwkv_heads", None, None)),
        "x_tm": (jax.ShapeDtypeStruct((batch, d), jnp.float32), ("batch", None)),
        "x_cm": (jax.ShapeDtypeStruct((batch, d), jnp.float32), ("batch", None)),
    }


def _shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """xx_t = x_{t-1}; token 0 sees `prev` (decode state) or zeros."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None].astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _ddlerp(p: dict, x: jax.Array, xx: jax.Array):
    """Finch data-dependent token-shift mixes for the 5 streams."""
    dx = xx - x
    z = x + dx * p["mu_x"].astype(x.dtype)
    za = jnp.tanh(jnp.einsum("bsd,dkl->bskl", z, p["mix_A"].astype(x.dtype)))
    mixes = (p["mu"].astype(x.dtype)
             + jnp.einsum("bskl,kld->bskd", za, p["mix_B"].astype(x.dtype)))
    return tuple(x + dx * mixes[:, :, i] for i in range(_N_MIX))  # w,k,v,r,g


def wkv_chunked(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
                u: jax.Array, s0: jax.Array, chunk: int = 64):
    """Chunked-parallel WKV6.  All (B,S,H,N) in f32; s0 (B,H,N,N).

    y_t = r_t . (S_{t-1} + (u*k_t) v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    B, S, H, N = r.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    rs, ks_, vs, ws = (jnp.moveaxis(a.reshape(B, nc, c, H, N), 1, 0)
                       for a in (r, k, v, log_w))

    def step(S_, inp):
        rc, kc, vc, lwc = inp  # (B, c, H, N)
        p = jnp.cumsum(lwc, axis=1)  # inclusive log-decay
        p_prev = p - lwc  # exclusive (through t-1)
        y_inter = jnp.einsum("blhn,bhnm->blhm", rc * jnp.exp(p_prev), S_)
        # intra-chunk: A[t,s] = sum_n r_t[n] k_s[n] exp(p_prev[t,n] - p[s,n]), s<t
        diff = p_prev[:, :, None] - p[:, None, :]  # (B, c, c, H, N)
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)[None, :, :, None, None]
        D = jnp.where(tri, jnp.exp(diff), 0.0)
        A = jnp.einsum("blhn,bmhn,blmhn->blmh", rc, kc, D)
        y_intra = jnp.einsum("blmh,bmhn->blhn", A, vc)
        bonus = jnp.einsum("blhn,hn,blhn->blh", rc, u, kc)
        y = y_inter + y_intra + bonus[..., None] * vc
        k_hat = kc * jnp.exp(p[:, -1:, :] - p)
        S_new = (jnp.exp(p[:, -1])[..., None] * S_
                 + jnp.einsum("blhn,blhm->bhnm", k_hat, vc))
        return S_new, y

    s_fin, ys = jax.lax.scan(step, s0, (rs, ks_, vs, ws))
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, H, N), s_fin


def wkv_recurrent(r, k, v, log_w, u, s0):
    """Naive per-step oracle (and the decode step when S==1)."""
    def step(S_, inp):
        rt, kt, vt, lwt = inp  # (B, H, N)
        y = (jnp.einsum("bhn,bhnm->bhm", rt, S_)
             + jnp.einsum("bhn,hn,bhn->bh", rt, u, kt)[..., None] * vt)
        S_new = jnp.exp(lwt)[..., None] * S_ + kt[..., None] * vt[:, :, None, :]
        return S_new, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, log_w))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_fin


def _group_norm(y: jax.Array, w: jax.Array, b: jax.Array, n: int,
                eps: float = 1e-5) -> jax.Array:
    B, S, d = y.shape
    yh = y.reshape(B, S, d // n, n).astype(jnp.float32)
    mean = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(B, S, d) * w.astype(jnp.float32)
            + b.astype(jnp.float32)).astype(y.dtype)


def apply_rwkv_time_mix(p: dict, cfg: ModelConfig, x: jax.Array,
                        state: dict | None, mode: str,
                        use_kernel: bool = False,
                        active: jax.Array | None = None):
    cdt = cfg.compute_dtype
    B, S, d = x.shape
    h, n = d // cfg.rwkv_head_size, cfg.rwkv_head_size

    # chunk_prefill continues a prefix: token 0 shifts against the cached
    # last-token activation (zeros when fresh, == _shift's zero pad)
    prev = (state["x_tm"] if (state is not None
                              and mode in ("decode", "chunk_prefill"))
            else None)
    xx = _shift(x, prev)
    xw, xk, xv, xr, xg = _ddlerp(p, x, xx)

    r = (xr @ p["w_r"].astype(cdt)).reshape(B, S, h, n)
    k = (xk @ p["w_k"].astype(cdt)).reshape(B, S, h, n)
    v = (xv @ p["w_v"].astype(cdt)).reshape(B, S, h, n)
    g = xg @ p["w_g"].astype(cdt)
    w_raw = (p["w0"].astype(jnp.float32)
             + jnp.tanh(xw @ p["decay_A"].astype(cdt)).astype(jnp.float32)
             @ p["decay_B"].astype(jnp.float32))
    log_w = -jnp.exp(w_raw).reshape(B, S, h, n)

    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))
    r32 = constrain(r32, "batch", None, "rwkv_heads", None)
    k32 = constrain(k32, "batch", None, "rwkv_heads", None)
    v32 = constrain(v32, "batch", None, "rwkv_heads", None)
    log_w = constrain(log_w, "batch", None, "rwkv_heads", None)
    u = p["u"].astype(jnp.float32)
    s0 = (state["S"] if state is not None
          else jnp.zeros((B, h, n, n), jnp.float32))

    if mode == "decode":
        y, s_fin = wkv_recurrent(r32, k32, v32, log_w, u, s0)
    elif use_kernel:
        from repro.kernels import ops as kops
        y, s_fin = kops.linear_scan(r32, k32, v32, log_w, u, s0)
    else:
        y, s_fin = wkv_chunked(r32, k32, v32, log_w, u, s0)

    y = _group_norm(y.reshape(B, S, d).astype(cdt), p["ln_w"], p["ln_b"], n)
    out = (y * jax.nn.silu(g)) @ p["w_o"].astype(cdt)
    out = constrain(out, "batch", "seq_act", None)

    new_state = None
    if state is not None:
        x_tm = x[:, -1].astype(jnp.float32)
        if active is not None:  # inactive slots keep their state verbatim
            s_fin = jnp.where(active[:, None, None, None], s_fin, state["S"])
            x_tm = jnp.where(active[:, None], x_tm, state["x_tm"])
        new_state = {"S": s_fin, "x_tm": x_tm, "x_cm": state["x_cm"]}
    return out, new_state


def apply_rwkv_channel_mix(p: dict, cfg: ModelConfig, x: jax.Array,
                           state: dict | None, mode: str,
                           active: jax.Array | None = None):
    cdt = cfg.compute_dtype
    prev = (state["x_cm"] if (state is not None
                              and mode in ("decode", "chunk_prefill"))
            else None)
    xx = _shift(x, prev)
    dx = xx - x
    xk = x + dx * p["mu_k"].astype(cdt)
    xr = x + dx * p["mu_r"].astype(cdt)
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(cdt)))
    out = jax.nn.sigmoid(xr @ p["w_r"].astype(cdt)) * (kk @ p["w_v"].astype(cdt))
    new_state = None
    if state is not None:
        x_cm = x[:, -1].astype(jnp.float32)
        if active is not None:
            x_cm = jnp.where(active[:, None], x_cm, state["x_cm"])
        new_state = {**state, "x_cm": x_cm}
    return constrain(out, "batch", "seq_act", None), new_state
