"""Multi-head Latent Attention (DeepSeek-V2 [arXiv:2405.04434], MiniCPM3).

Train/prefill use the expanded path (latent -> per-head K/V).  Decode uses the
*weight-absorbed* path: scores and attention outputs are computed directly in
the compressed latent space, so the KV cache holds only
(kv_lora_rank + qk_rope_head_dim) floats per token — the paper's 93.3% cache
reduction — and per-step FLOPs stay O(S * kv_lora) instead of O(S * H * dh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.attention import ModelCtx, attention_core, kv_heads_shardable
from repro.models.layers import Param, apply_norm, apply_rope, dense_init


def init_mla(key: jax.Array, cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    nope, rope, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qk = nope + rope
    dt = cfg.param_dtype
    ks = jax.random.split(key, 8)
    p: dict = {}
    if cfg.q_lora_rank:
        p["w_dq"] = Param(dense_init(ks[0], (d, cfg.q_lora_rank), 1, dt),
                          ("embed_fsdp", "lora"))
        p["q_norm"] = {"scale": Param(jnp.ones((cfg.q_lora_rank,), dt), (None,))}
        p["w_uq"] = Param(dense_init(ks[1], (cfg.q_lora_rank, h, qk), 1, dt),
                          ("lora", "heads", None))
    else:
        p["w_uq"] = Param(dense_init(ks[1], (d, h, qk), 1, dt),
                          ("embed_fsdp", "heads", None))
    p["w_dkv"] = Param(dense_init(ks[2], (d, cfg.kv_lora_rank), 1, dt),
                       ("embed_fsdp", "lora"))
    p["kv_norm"] = {"scale": Param(jnp.ones((cfg.kv_lora_rank,), dt), (None,))}
    p["w_kr"] = Param(dense_init(ks[3], (d, rope), 1, dt), ("embed_fsdp", None))
    p["w_uk"] = Param(dense_init(ks[4], (cfg.kv_lora_rank, h, nope), 1, dt),
                      ("lora", "heads", None))
    p["w_uv"] = Param(dense_init(ks[5], (cfg.kv_lora_rank, h, vdim), 1, dt),
                      ("lora", "heads", None))
    p["w_o"] = Param(dense_init(ks[6], (h, vdim, d), 2, dt),
                     ("heads", None, "embed_fsdp"))
    return p


def _rms(p_scale: jax.Array, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    sub = {"scale": p_scale}
    fake = cfg.scaled(norm_type="rmsnorm", gemma_norm=False)
    return apply_norm(sub, fake, x)


def _queries(p: dict, cfg: ModelConfig, x: jax.Array, ctx: ModelCtx):
    """MLA's low-rank structure doubles as a communication compressor: the
    down-projection runs *sequence-sharded* (local), and only the q_lora_rank
    latent crosses the SP->TP boundary — 1536 of 5120 dims on deepseek-v2
    (§Perf iteration 6)."""
    cdt = cfg.compute_dtype
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    heads_tp = kv_heads_shardable(cfg.n_heads)
    gather = heads_tp and x.shape[1] > 1
    if cfg.q_lora_rank:
        cq = x @ p["w_dq"].astype(cdt)
        cq = _rms(p["q_norm"]["scale"], cfg, cq)
        if gather:
            cq = constrain(cq, "batch", None, None)  # SP->TP on the latent
        q = jnp.einsum("bsl,lhk->bshk", cq, p["w_uq"].astype(cdt))
    else:
        if gather:
            x = constrain(x, "batch", None, None)
        q = jnp.einsum("bsd,dhk->bshk", x, p["w_uq"].astype(cdt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, ctx.positions, cfg, rot_dim=rope)
    return q_nope, q_rope


def _latents(p: dict, cfg: ModelConfig, x: jax.Array, ctx: ModelCtx):
    """Compressed per-token cache content: normed c_kv + roped shared k_rope."""
    cdt = cfg.compute_dtype
    ckv = x @ p["w_dkv"].astype(cdt)
    ckv = _rms(p["kv_norm"]["scale"], cfg, ckv)
    kr = (x @ p["w_kr"].astype(cdt))[:, :, None, :]  # (B,S,1,rope)
    kr = apply_rope(kr, ctx.positions, cfg, rot_dim=cfg.qk_rope_head_dim)[:, :, 0]
    return ckv, kr


def make_mla_cache(batch: int, size: int, cfg: ModelConfig, dtype) -> dict:
    return {
        "ckv": jnp.zeros((batch, size, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, size, cfg.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, size), -1, jnp.int32),
    }


def mla_cache_specs(batch: int, size: int, cfg: ModelConfig, dtype) -> dict:
    ax = ("batch", "kv_seq", None)
    return {
        "ckv": (jax.ShapeDtypeStruct((batch, size, cfg.kv_lora_rank), dtype), ax),
        "kr": (jax.ShapeDtypeStruct((batch, size, cfg.qk_rope_head_dim), dtype), ax),
        "pos": (jax.ShapeDtypeStruct((batch, size), jnp.int32), ("batch", "kv_seq")),
    }


def apply_mla(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    ctx: ModelCtx,
    cache: dict | None,
) -> tuple[jax.Array, dict | None]:
    cdt = cfg.compute_dtype
    B, S, _ = x.shape
    h = cfg.n_heads
    nope, rope, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    heads_tp = kv_heads_shardable(h)

    q_nope, q_rope = _queries(p, cfg, x, ctx)

    if ctx.mode in ("decode", "chunk_prefill"):
        assert cache is not None
        # ---- absorbed path (latent-space attention) ------------------------
        # decode: one new token per slot; chunk_prefill: a chunk of C tokens
        # continuing a prefix already in the cache.  Both write their latents
        # into the cache and attend with a (B, Q, S) position mask, so the
        # multi-query case is the exact generalization of single-token decode.
        ckv_t, kr_t = _latents(p, cfg, x, ctx)
        size = cache["ckv"].shape[1]
        if ctx.mode == "decode":
            b_idx = jnp.arange(B)
            # pos < 0 = inactive slot: write lands out of bounds -> dropped
            slots = jnp.where(ctx.cache_pos >= 0, ctx.cache_pos % size, size)
            new_cache = {
                "ckv": cache["ckv"].at[b_idx, slots].set(ckv_t[:, 0].astype(cache["ckv"].dtype)),
                "kr": cache["kr"].at[b_idx, slots].set(kr_t[:, 0].astype(cache["kr"].dtype)),
                "pos": cache["pos"].at[b_idx, slots].set(ctx.cache_pos),
            }
            ckv = constrain(new_cache["ckv"], "batch", "kv_seq", None).astype(cdt)
            kr = constrain(new_cache["kr"], "batch", "kv_seq", None).astype(cdt)
            pos_k = new_cache["pos"]
            pos_q = ctx.cache_pos[:, None]  # (B, 1)
        else:
            pos_q = ctx.pos2d  # (B, C)
            slots = pos_q % size
            b_idx = jnp.arange(B)[:, None]
            new_cache = {
                "ckv": cache["ckv"].at[b_idx, slots].set(ckv_t.astype(cache["ckv"].dtype)),
                "kr": cache["kr"].at[b_idx, slots].set(kr_t.astype(cache["kr"].dtype)),
                "pos": cache["pos"].at[b_idx, slots].set(pos_q),
            }
            # attend over (old cache contents ∪ this chunk); empty cache slots
            # carry pos == -1 and drop out of the mask
            ckv = jnp.concatenate([cache["ckv"].astype(cdt), ckv_t], axis=1)
            kr = jnp.concatenate([cache["kr"].astype(cdt), kr_t], axis=1)
            pos_k = jnp.concatenate([cache["pos"], pos_q], axis=1)

        # absorb W_uk into q: (B,Q,H,nope) x (lora,H,nope) -> (B,Q,H,lora)
        q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope, p["w_uk"].astype(cdt))
        s = jnp.einsum("bqhl,bsl->bhqs", q_lat, ckv,
                       preferred_element_type=jnp.float32)
        s += jnp.einsum("bqhr,bsr->bhqs", q_rope, kr,
                        preferred_element_type=jnp.float32)
        s *= (nope + rope) ** -0.5
        mask = (pos_k[:, None, :] >= 0) & (pos_k[:, None, :] <= pos_q[:, :, None])
        s = jnp.where(mask[:, None], s, -0.7 * jnp.finfo(jnp.float32).max)
        w = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhqs,bsl->bqhl", w.astype(cdt), ckv)
        o = jnp.einsum("bqhl,lhv->bqhv", o_lat, p["w_uv"].astype(cdt))
    else:
        # ---- expanded train/prefill path -----------------------------------
        # latents computed sequence-sharded; only (kv_lora + rope) dims cross
        # the SP->TP boundary (512+64 of 5120 on deepseek-v2)
        ckv, kr = _latents(p, cfg, x, ctx)
        head_ax = "heads" if heads_tp else None
        seq_ax = None if heads_tp else "seq_act"
        if heads_tp and S > 1:
            ckv = constrain(ckv, "batch", None, None)
            kr = constrain(kr, "batch", None, None)
        k_nope = jnp.einsum("bsl,lhn->bshn", ckv, p["w_uk"].astype(cdt))
        k_nope = constrain(k_nope, "batch", seq_ax, head_ax, None)
        v = jnp.einsum("bsl,lhv->bshv", ckv, p["w_uv"].astype(cdt))
        v = constrain(v, "batch", seq_ax, head_ax, None)
        # pin the head-broadcast rope key + the concat so GSPMD keeps the TP
        # head sharding through them (a broadcast+concat otherwise replicated
        # all 128 heads per q-chunk on deepseek-v2 — §Perf iteration 4)
        kr_b = constrain(jnp.broadcast_to(kr[:, :, None, :], (B, S, h, rope)),
                         "batch", seq_ax, head_ax, None)
        k = constrain(jnp.concatenate([k_nope, kr_b], axis=-1),
                      "batch", seq_ax, head_ax, None)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        q = constrain(q, "batch", seq_ax, head_ax, None)
        pos = ctx.pos2d
        o = attention_core(q, k, v, pos, pos, causal=ctx.causal)
        new_cache = None
        if cache is not None:  # prefill: persist compressed latents
            size = cache["ckv"].shape[1]
            ckv_w = ckv[:, -size:] if S > size else ckv
            kr_w = kr[:, -size:] if S > size else kr
            p_w = pos[:, -size:] if S > size else pos
            slots = p_w % size
            b_idx = jnp.arange(B)[:, None]
            new_cache = {
                "ckv": cache["ckv"].at[b_idx, slots].set(ckv_w.astype(cache["ckv"].dtype)),
                "kr": cache["kr"].at[b_idx, slots].set(kr_w.astype(cache["kr"].dtype)),
                "pos": cache["pos"].at[b_idx, slots].set(p_w),
            }

    o = constrain(o, "batch", None if heads_tp else "seq_act",
                  "heads" if heads_tp else None, None)
    out = jnp.einsum("bshv,hvd->bsd", o, p["w_o"].astype(cdt))
    return constrain(out, "batch", "seq_act", None), new_cache
