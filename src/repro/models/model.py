"""LanguageModel: unified init / train_loss / prefill / decode_step for all
ten assigned architectures (dense, MoE, MLA, hybrid, SSM, enc-dec, VLM).

Pure-functional: ``init`` returns a plain array pytree; the logical sharding
axes for every parameter are captured as a parallel tree (``param_axes``).
The same apply code runs un-sharded in unit tests and under GSPMD on the
production meshes (sharding constraints no-op without an active mesh).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import transformer as tfm
from repro.models.attention import ModelCtx
from repro.models.layers import (Param, apply_norm, embed_init, init_norm,
                                 sinusoidal_positions, split)


class LanguageModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dec_kinds = tfm.layer_kinds(cfg, decoder=cfg.enc_dec)
        self.dec_segments = tfm.plan_segments(cfg, self.dec_kinds)
        self.enc_segments = []
        if cfg.enc_dec:
            enc_kinds = [("attn", False)] * cfg.n_enc_layers
            self.enc_segments = tfm.plan_segments(cfg, enc_kinds)
        self._axes: dict | None = None

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        axes: dict[str, Any] = {}
        params: dict[str, Any] = {}
        n_keys = 8 + len(self.dec_segments) + len(self.enc_segments)
        ks = list(jax.random.split(key, n_keys))

        def take(p: Param):
            return p.value, tuple(p.axes)

        params["embed"], axes["embed"] = take(Param(
            embed_init(ks.pop(), (cfg.vocab_size, cfg.d_model), cfg.param_dtype),
            ("vocab", "embed_fsdp")))
        if not cfg.tie_embeddings:
            params["out"], axes["out"] = take(Param(
                embed_init(ks.pop(), (cfg.d_model, cfg.vocab_size), cfg.param_dtype),
                ("embed_fsdp", "vocab")))
        if cfg.pos_type == "learned":
            params["pos_embed"], axes["pos_embed"] = take(Param(
                embed_init(ks.pop(), (cfg.max_positions, cfg.d_model),
                           cfg.param_dtype),
                (None, "embed_fsdp")))
        if cfg.embed_norm:
            v, a = split(init_norm(cfg, cfg.d_model))
            params["embed_ln"], axes["embed_ln"] = v, a

        for i, seg in enumerate(self.dec_segments):
            cap: dict = {}
            params[f"seg{i}"] = tfm.init_segment(ks.pop(), cfg, seg, cap)
            axes[f"seg{i}"] = cap["axes"]
        v, a = split(init_norm(cfg, cfg.d_model))
        params["final_norm"], axes["final_norm"] = v, a

        if cfg.enc_dec:
            enc_p: dict = {}
            enc_a: dict = {}
            for i, seg in enumerate(self.enc_segments):
                cap = {}
                enc_p[f"seg{i}"] = tfm.init_segment(ks.pop(), cfg, seg, cap)
                enc_a[f"seg{i}"] = cap["axes"]
            v, a = split(init_norm(cfg, cfg.d_model))
            enc_p["final_norm"], enc_a["final_norm"] = v, a
            params["enc"], axes["enc"] = enc_p, enc_a

        self._axes = axes
        return params

    @property
    def param_axes(self) -> dict:
        if self._axes is None:
            jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return self._axes  # type: ignore[return-value]

    def abstract_params(self) -> dict:
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # ------------------------------------------------------------- embeddings
    def _embed(self, params: dict, tokens: jax.Array,
               embeds: jax.Array | None = None) -> jax.Array:
        cfg = self.cfg
        cdt = cfg.compute_dtype
        if embeds is not None:
            x = embeds.astype(cdt)  # modality-frontend stub output
        else:
            x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
        if cfg.emb_scale:
            x = x * math.sqrt(cfg.d_model)
        if cfg.embed_norm:
            x = apply_norm(params["embed_ln"], cfg, x)
        return constrain(x, "batch", "seq_act", None)

    def _head(self, params: dict, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = apply_norm(params["final_norm"], cfg, x)
        w = params["embed"].T if cfg.tie_embeddings else params["out"]
        logits = (x @ w.astype(cfg.compute_dtype)).astype(jnp.float32)
        return constrain(logits, "batch", "seq_act", "vocab")

    def _positions(self, batch_size: int, seq: int,
                   given: jax.Array | None) -> jax.Array:
        if given is not None:
            return given
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch_size, seq))
        if self.cfg.pos_type == "mrope":
            pos = jnp.broadcast_to(pos, (3, batch_size, seq))
        return pos

    # --------------------------------------------------------------- encoder
    def _encode(self, params: dict, frames: jax.Array) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        B, S, _ = frames.shape
        x = frames.astype(cfg.compute_dtype)
        x = x + sinusoidal_positions(S, cfg.d_model, x.dtype)[None]
        x = constrain(x, "batch", "seq_act", None)
        pos = self._positions(B, S, None)
        ctx = ModelCtx(mode="encode", positions=pos, causal=False)
        enc_axes = self.param_axes.get("enc", {})
        for i, seg in enumerate(self.enc_segments):
            x, _, _ = tfm.apply_segment(params["enc"][f"seg{i}"], cfg, seg, x,
                                        None, ctx, axes=enc_axes.get(f"seg{i}"))
        x = apply_norm(params["enc"]["final_norm"], cfg, x)
        return x, pos

    def _cast_for_compute(self, params: dict) -> dict:
        """Cast >=2D float params to the compute dtype *before* use: the cast
        runs on local FSDP shards, so per-layer all-gathers move bf16, not
        f32 (halves FSDP gather traffic — EXPERIMENTS.md §Perf)."""
        cdt = self.cfg.compute_dtype
        if jnp.dtype(cdt) == jnp.dtype(self.cfg.param_dtype):
            return params

        def cast(x):
            if (hasattr(x, "dtype") and x.ndim >= 2
                    and jnp.issubdtype(x.dtype, jnp.floating)):
                return x.astype(cdt)
            return x

        return jax.tree.map(cast, params)

    def _backbone(self, params: dict, x: jax.Array, caches: Any,
                  ctx: ModelCtx) -> tuple[jax.Array, Any, jax.Array]:
        new_caches = {} if caches is not None else None
        aux = jnp.zeros((), jnp.float32)
        axes = self.param_axes
        for i, seg in enumerate(self.dec_segments):
            c = None if caches is None else caches[f"seg{i}"]
            x, nc, a = tfm.apply_segment(params[f"seg{i}"], self.cfg, seg, x,
                                         c, ctx, axes=axes.get(f"seg{i}"))
            aux = aux + a
            if new_caches is not None:
                new_caches[f"seg{i}"] = nc
        return x, new_caches, aux

    # ------------------------------------------------------------------ train
    def train_loss(self, params: dict, batch: dict) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        targets = batch["targets"]
        weights = batch.get("weights")
        if weights is None:
            weights = jnp.ones_like(tokens, jnp.float32)
        params = self._cast_for_compute(params)
        pos = self._positions(B, S, batch.get("positions"))
        ctx = ModelCtx(mode="train", positions=pos)
        if cfg.enc_dec:
            enc_out, enc_pos = self._encode(params, batch["frames"])
            ctx = ModelCtx(mode="train", positions=pos, enc_out=enc_out,
                           enc_positions=enc_pos)

        x = self._embed(params, tokens, batch.get("embeds"))
        if cfg.pos_type == "learned":
            x = x + jnp.take(params["pos_embed"], pos, axis=0).astype(x.dtype)
        x, _, aux = self._backbone(params, x, None, ctx)
        logits = self._head(params, x)

        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(targets, cfg.vocab_size, dtype=logits.dtype)
        label_logit = jnp.sum(onehot * logits, axis=-1)
        nll = (lse - label_logit) * weights
        denom = jnp.maximum(weights.sum(), 1.0)
        loss = nll.sum() / denom
        total = loss + cfg.router_aux_coef * aux
        metrics = {"loss": loss, "aux_loss": aux, "tokens": denom,
                   "total_loss": total}
        return total, metrics

    # ------------------------------------------------------------------ serve
    def cache_specs(self, batch: int, max_len: int, enc_len: int = 0,
                    dtype=jnp.bfloat16,
                    pages: tuple[int, int] | None = None) -> dict:
        """``pages=(n_pages, page_size)`` swaps full-attention KV caches for
        shared page pools (no batch dim; see launch/paged_kv.py).  All other
        cache kinds (SWA rings, cross, MLA latents, recurrent states) remain
        slot-dense with ``batch`` rows."""
        specs = {}
        for i, seg in enumerate(self.dec_segments):
            specs[f"seg{i}"] = tfm.segment_cache_specs(
                self.cfg, seg, batch, max_len, enc_len or max_len, dtype,
                pages=pages)
        return specs

    def init_cache(self, batch: int, max_len: int, enc_len: int = 0,
                   dtype=jnp.bfloat16,
                   pages: tuple[int, int] | None = None) -> dict:
        def make(leaf):
            sds, _ = leaf
            if sds.dtype == jnp.int32:  # slot-position arrays start empty
                return jnp.full(sds.shape, -1, sds.dtype)
            return jnp.zeros(sds.shape, sds.dtype)

        return jax.tree.map(
            make, self.cache_specs(batch, max_len, enc_len, dtype,
                                   pages=pages),
            is_leaf=_is_spec_leaf)

    def prefill(self, params: dict, batch: dict, cache: dict) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        pos = self._positions(B, S, batch.get("positions"))
        ctx = ModelCtx(mode="prefill", positions=pos)
        if cfg.enc_dec:
            enc_out, enc_pos = self._encode(params, batch["frames"])
            ctx = ModelCtx(mode="prefill", positions=pos, enc_out=enc_out,
                           enc_positions=enc_pos)
        x = self._embed(params, tokens, batch.get("embeds"))
        if cfg.pos_type == "learned":
            x = x + jnp.take(params["pos_embed"], pos, axis=0).astype(x.dtype)
        x, new_cache, _ = self._backbone(params, x, cache, ctx)
        logits = self._head(params, x[:, -1:])[:, 0]
        return logits, new_cache

    def prefill_chunk(self, params: dict, batch: dict, cache: dict,
                      start: jax.Array) -> tuple[jax.Array, dict]:
        """Continue prefilling an existing cache with one chunk of tokens.

        batch["tokens"]: (B, C); start: (B,) absolute position of the chunk's
        first token.  Attends over (cache contents ∪ chunk), so calling this
        repeatedly over an exact partition of the prompt is equivalent to one
        full ``prefill`` — no padding, no masking approximations.  Returns the
        last-position logits (the argmax seed once the prompt is exhausted)
        and the updated cache.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, C = tokens.shape
        pos = start[:, None].astype(jnp.int32) + jnp.arange(C, dtype=jnp.int32)
        if cfg.pos_type == "mrope":
            pos = jnp.broadcast_to(pos, (3, B, C))
        ctx = ModelCtx(mode="chunk_prefill", positions=pos)
        if cfg.enc_dec:
            enc_out, enc_pos = self._encode(params, batch["frames"])
            ctx = ModelCtx(mode="chunk_prefill", positions=pos,
                           enc_out=enc_out, enc_positions=enc_pos)
        x = self._embed(params, tokens, batch.get("embeds"))
        if cfg.pos_type == "learned":
            x = x + jnp.take(params["pos_embed"], pos, axis=0).astype(x.dtype)
        x, new_cache, _ = self._backbone(params, x, cache, ctx)
        logits = self._head(params, x[:, -1:])[:, 0]
        return logits, new_cache

    def decode_step(self, params: dict, tokens: jax.Array, cache: dict,
                    pos: jax.Array,
                    table: jax.Array | None = None) -> tuple[jax.Array, dict]:
        """tokens: (B, 1); pos: (B,) current positions (0-based).  ``table``
        is the (B, max_pages) block table when ``cache`` holds paged pools."""
        cfg = self.cfg
        B = tokens.shape[0]
        positions = pos[:, None].astype(jnp.int32)
        if cfg.pos_type == "mrope":
            positions = jnp.broadcast_to(positions, (3, B, 1))
        ctx = ModelCtx(mode="decode", positions=positions, cache_pos=pos,
                       table=table)
        x = self._embed(params, tokens)
        if cfg.pos_type == "learned":
            x = x + jnp.take(params["pos_embed"], positions, axis=0).astype(x.dtype)
        x, new_cache, _ = self._backbone(params, x, cache, ctx)
        logits = self._head(params, x)[:, 0]
        return logits, new_cache


def _is_spec_leaf(x: Any) -> bool:
    return (isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], jax.ShapeDtypeStruct))
