"""Block composition: per-layer kinds -> scanned segments.

Layers are grouped into *segments*: a maximal run of layers whose cyclic
super-block (e.g. Griffin's (rglru, rglru, swa)) repeats >= 2 times is scanned
with ``jax.lax.scan`` (keeping HLO compact and making FSDP all-gathers land
inside the loop body); leftovers are unrolled.  Examples:

  deepseek-v2-236b : [dense x1 unrolled] + [moe x59 scanned]
  recurrentgemma-9b: [(rglru,rglru,swa) x12 scanned] + [rglru, rglru unrolled]
  gemma-2b         : [(attn) x18 scanned]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import recurrent as rec_mod
from repro.models.attention import ModelCtx
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm, split

LayerKind = tuple[str, bool]  # (block type, is_moe)


@dataclasses.dataclass(frozen=True)
class Segment:
    kinds: tuple[LayerKind, ...]  # the super-block
    repeats: int
    scanned: bool

    @property
    def n_layers(self) -> int:
        return len(self.kinds) * self.repeats


def layer_kinds(cfg: ModelConfig, decoder: bool = False) -> list[LayerKind]:
    if decoder:
        return [("xattn", False)] * cfg.n_layers
    kinds = []
    for i, t in enumerate(cfg.layer_types()):
        moe = (cfg.n_experts > 0 and i >= cfg.first_dense_layers
               and t in ("attn", "swa"))
        kinds.append((t, moe))
    return kinds


def plan_segments(cfg: ModelConfig, kinds: list[LayerKind]) -> list[Segment]:
    p = max(1, len(cfg.layer_pattern))
    segs: list[Segment] = []
    i, n = 0, len(kinds)
    while i < n:
        block = tuple(kinds[i : i + p])
        reps = 0
        j = i
        while j + p <= n and tuple(kinds[j : j + p]) == block:
            reps += 1
            j += p
        if reps >= 2:
            segs.append(Segment(block, reps, scanned=True))
            i = j
        else:
            segs.append(Segment((kinds[i],), 1, scanned=False))
            i += 1
    return segs


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------


def init_layer(key: jax.Array, cfg: ModelConfig, kind: LayerKind) -> dict:
    t, is_moe = kind
    ks = jax.random.split(key, 6)
    p: dict = {"norm1": init_norm(cfg, cfg.d_model)}
    if t in ("attn", "swa"):
        p["core"] = attn_mod.init_attention(ks[0], cfg)
        if cfg.use_mla:
            p["core"] = mla_mod.init_mla(ks[0], cfg)
    elif t == "xattn":
        p["core"] = attn_mod.init_attention(ks[0], cfg)
        p["norm_x"] = init_norm(cfg, cfg.d_model)
        p["cross"] = attn_mod.init_attention(ks[1], cfg, cross=True)
    elif t == "rglru":
        p["core"] = rec_mod.init_rglru(ks[0], cfg)
    elif t == "rwkv6":
        p["core"] = rec_mod.init_rwkv_time_mix(ks[0], cfg)
    else:
        raise ValueError(t)

    p["norm2"] = init_norm(cfg, cfg.d_model)
    if t == "rwkv6":
        p["mlp"] = rec_mod.init_rwkv_channel_mix(ks[2], cfg)
    elif is_moe:
        p["moe"] = moe_mod.init_moe(ks[2], cfg)
        if cfg.n_shared_experts:
            p["shared"] = init_mlp(ks[3], cfg,
                                   cfg.n_shared_experts * cfg.d_ff_expert)
    else:
        p["mlp"] = init_mlp(ks[2], cfg)
    return p


def cache_specs_for_kind(cfg: ModelConfig, kind: LayerKind, batch: int,
                         max_len: int, enc_len: int, dtype,
                         pages: tuple[int, int] | None = None) -> Any:
    """``pages=(n_pages, page_size)`` swaps full-attention KV caches for
    shared page pools (block-table indirection; see launch/paged_kv.py).
    SWA rings, cross caches, MLA latents and recurrent states stay slot-dense
    — they are O(window)/O(1) per slot, so paging buys nothing there."""
    t, _ = kind
    if t == "swa":
        size = min(cfg.window, max_len) if cfg.window else max_len
        return attn_mod.kv_cache_specs(batch, size, cfg.n_kv_heads,
                                       cfg.head_dim, cfg.head_dim, dtype)
    if t == "attn":
        if cfg.use_mla:
            return mla_mod.mla_cache_specs(batch, max_len, cfg, dtype)
        if pages is not None:
            return attn_mod.paged_kv_cache_specs(
                pages[0], pages[1], cfg.n_kv_heads, cfg.head_dim,
                cfg.head_dim, dtype)
        return attn_mod.kv_cache_specs(batch, max_len, cfg.n_kv_heads,
                                       cfg.head_dim, cfg.head_dim, dtype)
    if t == "xattn":
        return {
            "self": attn_mod.kv_cache_specs(batch, max_len, cfg.n_kv_heads,
                                            cfg.head_dim, cfg.head_dim, dtype),
            "cross": attn_mod.kv_cache_specs(batch, enc_len, cfg.n_kv_heads,
                                             cfg.head_dim, cfg.head_dim, dtype),
        }
    if t == "rglru":
        return rec_mod.rglru_state_specs(batch, cfg)
    if t == "rwkv6":
        return rec_mod.rwkv_state_specs(batch, cfg)
    raise ValueError(t)


def _active_mask(ctx: ModelCtx) -> jax.Array | None:
    """Per-slot liveness for decode: pos < 0 marks a slot whose recurrent
    state must pass through unchanged (it is being chunk-prefilled while the
    rest of the batch decodes)."""
    if ctx.mode == "decode" and ctx.cache_pos is not None:
        return ctx.cache_pos >= 0
    return None


def apply_layer(p: dict, cfg: ModelConfig, kind: LayerKind, x: jax.Array,
                cache: Any, ctx: ModelCtx) -> tuple[jax.Array, Any, jax.Array]:
    t, is_moe = kind
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], cfg, x)

    if t in ("attn", "swa"):
        window = cfg.window if t == "swa" else 0
        if cfg.use_mla:
            y, new_cache = mla_mod.apply_mla(p["core"], cfg, h, ctx, cache)
        else:
            # Only full-attention layers page; the flag (not cache structure
            # sniffing) decides, because inside a scanned segment the cache is
            # a tracer whose paged-ness can't be inspected.
            paged = (ctx.table is not None and t == "attn"
                     and ctx.mode == "decode")
            y, new_cache = attn_mod.apply_attention(p["core"], cfg, h, ctx,
                                                    cache, window=window,
                                                    paged=paged)
    elif t == "xattn":
        y, self_c = attn_mod.apply_attention(
            p["core"], cfg, h, ctx, None if cache is None else cache["self"])
        x = x + y
        hx = apply_norm(p["norm_x"], cfg, x)
        y, cross_c = attn_mod.apply_attention(
            p["cross"], cfg, hx, ctx,
            None if cache is None else cache["cross"], cross=True)
        new_cache = None if cache is None else {"self": self_c, "cross": cross_c}
    elif t == "rglru":
        y, new_cache = rec_mod.apply_rglru(p["core"], cfg, h, cache, ctx.mode,
                                           active=_active_mask(ctx))
    elif t == "rwkv6":
        y, new_cache = rec_mod.apply_rwkv_time_mix(
            p["core"], cfg, h, cache, ctx.mode, active=_active_mask(ctx))
    else:
        raise ValueError(t)
    x = x + y

    h = apply_norm(p["norm2"], cfg, x)
    if t == "rwkv6":
        y, new_cache = rec_mod.apply_rwkv_channel_mix(
            p["mlp"], cfg, h, new_cache, ctx.mode,
            active=_active_mask(ctx))
    elif is_moe:
        y, aux = moe_mod.apply_moe(p["moe"], cfg, h)
        if cfg.n_shared_experts:
            y = y + apply_mlp(p["shared"], cfg, h)
    else:
        y = apply_mlp(p["mlp"], cfg, h)
    x = x + y
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Super-blocks and segments
# ---------------------------------------------------------------------------


def init_superblock(key: jax.Array, cfg: ModelConfig,
                    kinds: tuple[LayerKind, ...]) -> dict:
    ks = jax.random.split(key, len(kinds))
    return {f"sub{i}": init_layer(ks[i], cfg, kind)
            for i, kind in enumerate(kinds)}


def apply_superblock(p: dict, cfg: ModelConfig, kinds: tuple[LayerKind, ...],
                     x: jax.Array, caches: Any, ctx: ModelCtx):
    new_caches = {}
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(kinds):
        c = None if caches is None else caches[f"sub{i}"]
        x, nc, a = apply_layer(p[f"sub{i}"], cfg, kind, x, c, ctx)
        aux = aux + a
        new_caches[f"sub{i}"] = nc
    return x, (None if caches is None else new_caches), aux


def init_segment(key: jax.Array, cfg: ModelConfig, seg: Segment,
                 captured_axes: dict) -> Any:
    """Returns the segment's value tree; records the axes tree (with a
    leading 'layers' axis for scanned segments) into ``captured_axes``."""

    def vals_fn(k):
        tree = init_superblock(k, cfg, seg.kinds)
        vals, axes = split(tree)
        captured_axes["axes"] = axes
        return vals

    if seg.scanned:
        vals = jax.vmap(vals_fn)(jax.random.split(key, seg.repeats))
        captured_axes["axes"] = jax.tree.map(
            lambda a: ("layers",) + a, captured_axes["axes"],
            is_leaf=lambda a: isinstance(a, tuple)
            and all(isinstance(e, (str, type(None))) for e in a))
    else:
        vals = vals_fn(key)
    return vals


def segment_cache_specs(cfg: ModelConfig, seg: Segment, batch: int,
                        max_len: int, enc_len: int, dtype,
                        pages: tuple[int, int] | None = None) -> Any:
    per_block = {
        f"sub{i}": cache_specs_for_kind(cfg, kind, batch, max_len, enc_len,
                                        dtype, pages=pages)
        for i, kind in enumerate(seg.kinds)
    }
    if not seg.scanned:
        return per_block

    def stack(leaf):
        sds, axes = leaf
        return (jax.ShapeDtypeStruct((seg.repeats,) + sds.shape, sds.dtype),
                (None,) + tuple(axes))

    return jax.tree.map(stack, per_block,
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                        and isinstance(x[0], jax.ShapeDtypeStruct))


def _is_axes_leaf(a: Any) -> bool:
    return (isinstance(a, tuple)
            and all(isinstance(e, (str, type(None))) for e in a))


def _constrain_layer_params(p_layer: Any, axes: Any, scanned: bool) -> Any:
    """Pin each per-layer weight slice to its (TP x FSDP) shard layout inside
    the scan body.  The transpose of a sharding constraint is the same
    constraint, so the *gradient* of each weight is forced to the sharded
    layout right where it is produced — XLA then lowers the data-axis batch
    reduction as reduce-scatter instead of a full all-reduce + slice
    (EXPERIMENTS.md §Perf iteration 3)."""
    from repro.distributed.sharding import constrain

    if axes is None:
        return p_layer

    def apply(v, ax):
        ax = tuple(ax[1:]) if scanned else tuple(ax)
        return constrain(v, *ax)

    return jax.tree.map(apply, p_layer, axes)


def apply_segment(p: Any, cfg: ModelConfig, seg: Segment, x: jax.Array,
                  caches: Any, ctx: ModelCtx, axes: Any = None):
    if not seg.scanned:
        p = _constrain_layer_params(p, axes, scanned=False)
        return apply_superblock(p, cfg, seg.kinds, x, caches, ctx)

    fn = functools.partial(apply_superblock, cfg=cfg, kinds=seg.kinds, ctx=ctx)
    if ctx.mode == "train" and cfg.remat != "none":
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat == "full"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        fn = jax.checkpoint(fn, policy=policy)

    if caches is None:
        def body(carry, p_layer):
            x_, aux_ = carry
            p_layer = _constrain_layer_params(p_layer, axes, scanned=True)
            x_, _, a = fn(p_layer, x=x_, caches=None)
            return (x_, aux_ + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), p)
        return x, None, aux

    def body(carry, xs):
        x_, aux_ = carry
        p_layer, cache_layer = xs
        p_layer = _constrain_layer_params(p_layer, axes, scanned=True)
        x_, nc, a = fn(p_layer, x=x_, caches=cache_layer)
        return (x_, aux_ + a), nc

    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (p, caches))
    return x, new_caches, aux
