"""Small shared helpers (no jax device-state side effects at import)."""
from __future__ import annotations

import math
import time
from typing import Any, Iterator

import jax
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


def tree_bytes(tree: Any) -> int:
    total = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            total += int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    return total


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} EiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


def human_time(seconds: float) -> str:
    if seconds < 1e-3:
        return f"{seconds * 1e6:.1f} us"
    if seconds < 1.0:
        return f"{seconds * 1e3:.2f} ms"
    if seconds < 120.0:
        return f"{seconds:.2f} s"
    return f"{seconds / 60.0:.1f} min"


class Stopwatch:
    def __enter__(self) -> "Stopwatch":
        self.start = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.elapsed = time.perf_counter() - self.start


def round_up(x: int, multiple: int) -> int:
    return int(math.ceil(x / multiple) * multiple)


def chunks(seq: list, n: int) -> Iterator[list]:
    for i in range(0, len(seq), n):
        yield seq[i : i + n]
