"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP) over the production mesh.

Every tensor dim in the model is annotated with a *logical* axis name; this
module maps logical names -> mesh axes, with automatic fallback when a dim is
not divisible by the mesh axis size (e.g. kv_heads=8 on a 16-way model axis).

The mapping is carried in a context (``MeshInfo``) so the same model code runs
(a) un-sharded on a single CPU device in unit tests, (b) on a 16x16 single-pod
mesh, and (c) on the 2x16x16 multi-pod mesh, with no code changes.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> preferred mesh axes (in order; each mesh axis used at most
# once per tensor).  "batch" spreads over the pure-DP axes (pod + data);
# "*_fsdp" are ZeRO-3 weight shards over the data axis; "heads"/"mlp"/"vocab"/
# "experts" are tensor/expert parallel over the model axis; "seq_act" is
# Megatron-style sequence parallelism for the residual stream; "kv_seq" shards
# long KV caches / decode-time sequence over the model axis (SP-decode).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "batch_data_only": ("data",),
    "seq_act": ("model",),
    "kv_seq": ("model",),
    "embed_fsdp": ("data",),
    "ff_fsdp": ("data",),
    "vocab_fsdp": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_ff_fsdp": ("data",),
    "lru_width": ("model",),
    "rwkv_heads": ("model",),
    "layers": (),
    "head_dim": (),
    "qk_dim": (),
    "v_dim": (),
    "lora": (),
    "window": (),
    "conv": (),
    "state": (),
    "stats": (),
    None: (),
}


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    """A mesh plus the logical->physical rules active for this run."""

    mesh: Mesh
    rules: Mapping[str, tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES)
    )

    @property
    def axis_sizes(self) -> dict[str, int]:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    def axis_size(self, name: str) -> int:
        return self.axis_sizes.get(name, 1)

    def mesh_axes_for(self, logical: str | None, dim_size: int) -> tuple[str, ...]:
        """Resolve a logical axis to mesh axes, dropping axes that don't divide
        ``dim_size`` or don't exist in this mesh (divisibility fallback)."""
        axes: list[str] = []
        prod = 1
        for ax in self.rules.get(logical, ()):  # type: ignore[arg-type]
            size = self.axis_sizes.get(ax)
            if size is None or size <= 1:
                continue
            if dim_size % (prod * size) != 0:
                continue
            axes.append(ax)
            prod *= size
        return tuple(axes)

    def spec(self, shape: Sequence[int], axes: Sequence[str | None]) -> P:
        """PartitionSpec for a tensor with the given shape + logical axes.
        A mesh axis is only used once per tensor (first dim wins)."""
        assert len(shape) == len(axes), (shape, axes)
        used: set[str] = set()
        entries: list[Any] = []
        for dim, logical in zip(shape, axes):
            resolved = [a for a in self.mesh_axes_for(logical, dim) if a not in used]
            # re-check divisibility after dropping already-used axes
            prod = 1
            keep: list[str] = []
            for a in resolved:
                size = self.axis_sizes[a]
                if dim % (prod * size) == 0:
                    keep.append(a)
                    prod *= size
            used.update(keep)
            if not keep:
                entries.append(None)
            elif len(keep) == 1:
                entries.append(keep[0])
            else:
                entries.append(tuple(keep))
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def sharding(self, shape: Sequence[int], axes: Sequence[str | None]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, axes))


class _MeshState(threading.local):
    def __init__(self) -> None:
        self.info: MeshInfo | None = None


_STATE = _MeshState()


def set_mesh_info(info: MeshInfo | None) -> None:
    _STATE.info = info


def current_mesh_info() -> MeshInfo | None:
    return _STATE.info


@contextlib.contextmanager
def use_mesh_info(info: MeshInfo | None):
    prev = _STATE.info
    _STATE.info = info
    try:
        yield info
    finally:
        _STATE.info = prev


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """Apply with_sharding_constraint using logical axis names.

    No-op when no mesh is active (single-device tests) — the same model code
    is thereby portable between unit tests and pod-scale dry runs.
    """
    info = _STATE.info
    if info is None:
        return x
    return jax.lax.with_sharding_constraint(x, info.sharding(x.shape, axes))


def logical_spec(shape: Sequence[int], axes: Sequence[str | None]) -> P:
    info = _STATE.info
    if info is None:
        return P()
    return info.spec(shape, axes)


def param_shardings(axes_tree: Any, shape_tree: Any, info: MeshInfo) -> Any:
    """Build a NamedSharding tree from an axes tree + matching shape tree."""
    return jax.tree.map(
        lambda axes, shaped: info.sharding(shaped.shape, axes),
        axes_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def shard_map_specs(info: MeshInfo | None):
    """Convenience: (data_axes, model_axis) names present in the active mesh,
    for the explicit shard_map MoE path."""
    if info is None:
        return (), None
    names = info.mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    model_axis = "model" if "model" in names else None
    return data_axes, model_axis
