"""Optional GPipe-style pipeline parallelism over a mesh axis.

The assigned production mesh uses DP x TP (+pod DP), so PP is off by default;
this module exists because 1000+-node deployments of deep models want the
option (DESIGN.md §5).  Implementation: shard_map over the stage axis, a
static schedule of T = n_micro + n_stages - 1 ticks, ``lax.ppermute`` moving
activations stage->stage+1 each tick.  Differentiable (ppermute transposes to
the reverse permute), validated against the sequential reference in tests.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

# jax >= 0.6 renamed check_rep -> check_vma; disable either way (the bodies
# use collectives that the replication checker cannot verify)
_SM_NOCHECK = (
    {"check_vma": False}
    if "check_vma" in inspect.signature(_shard_map).parameters
    else {"check_rep": False})


def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,  # leaves stacked over n_stages on dim 0
    x: jax.Array,  # (n_micro, micro_batch, ...) microbatched input
    mesh: Mesh,
    axis: str = "model",
) -> jax.Array:
    """Runs x through n_stages sequential stages, pipelined over microbatches.

    stage_fn(params_for_one_stage, h) -> h, same shape (the classic GPipe
    restriction).  Returns (n_micro, micro_batch, ...) outputs.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    assert n_micro % 1 == 0 and n_micro >= 1

    def per_stage(params_l, x_l):
        # params_l: this stage's params (leading stage dim of size 1)
        params_l = jax.tree.map(lambda a: a[0], params_l)
        stage = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(x_l[0])  # current activation on this stage
        outs = jnp.zeros_like(x_l)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (others ignore feed)
            feed = jax.lax.dynamic_index_in_dim(
                x_l, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            h_in = jnp.where(stage == 0,
                             jnp.where(t < n_micro, 1.0, 0.0), 1.0) * \
                jnp.where(stage == 0, feed, buf)
            h_out = stage_fn(params_l, h_in)
            # last stage emits microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            emit = jnp.logical_and(stage == n_stages - 1, out_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h_out, jnp.clip(out_idx, 0, n_micro - 1), 0),
                lambda o: o, outs)
            # shift activations to the next stage
            buf_next = jax.lax.ppermute(
                h_out, axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf_next, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs),
                                    jnp.arange(n_ticks))
        return outs

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    out = _shard_map(
        per_stage, mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(axis),  # each stage returns outs; only last is real
        **_SM_NOCHECK,
    )(stage_params, x)
    # out has a stage-sharded leading dim view: (n_stages*n_micro, ...) after
    # concat; the real outputs live in the last stage's block
    return out.reshape(n_stages, n_micro, *x.shape[1:])[-1]
