from repro.distributed.sharding import (  # noqa: F401
    MeshInfo,
    constrain,
    current_mesh_info,
    logical_spec,
    param_shardings,
    set_mesh_info,
    use_mesh_info,
)
