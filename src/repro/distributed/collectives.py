"""Distributed-optimization collectives: int8 gradient compression with
error feedback for the slow cross-pod hop.

The 2x16x16 production mesh reduces gradients over the 'pod' axis across
data-center-interconnect-class links; int8 quantization cuts that traffic 4x
vs f32.  Error feedback (residual carrying, Seide et al. / 1-bit SGD lineage)
keeps SGD convergence unbiased — validated in tests on a quadratic and by an
end-to-end loss-parity run.

Usage: inside a shard_map over the pod axis, replace ``psum(g, 'pod')`` with
``compressed_psum(g, 'pod', state)``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str,
                    error: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Quantized mean-reduce over ``axis_name`` with error feedback.

    Returns (mean_estimate, new_error).  Communicates int8 payload (psum over
    int32 accumulators to avoid overflow: 127 * axis_size << 2^31) plus one
    f32 scale per tensor per participant (max-reduced).
    """
    x32 = x.astype(jnp.float32)
    if error is not None:
        x32 = x32 + error
    # shared scale so the integer sum is meaningful
    scale = jax.lax.pmax(jnp.max(jnp.abs(x32)), axis_name) / 127.0
    scale = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = total.astype(jnp.float32) * scale / n
    new_error = x32 - q.astype(jnp.float32) * scale  # local residual
    return mean.astype(x.dtype), new_error


def init_error_state(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum_tree(grads: Any, axis_name: str, errors: Any
                         ) -> tuple[Any, Any]:
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        m, ne = compressed_psum(g, axis_name, e)
        out_g.append(m)
        out_e.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))
