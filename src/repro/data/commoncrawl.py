"""The paper's example use case (§5): mining web-based inter-firm networks
from Common-Crawl-style data.

A deterministic synthetic WARC-like corpus stands in for CC-MAIN (the real
dataset is a remote multi-TB archive; the *pipeline semantics* — the paper's
contribution — are fully implemented).  The four assets match Figure 2:

    nodes      : extract + preprocess seed-node info
    edges      : extract hyperlinks from seed-node pages
    graph      : join nodes x edges into a hyperlink graph
    graph_aggr : aggregate the graph to domain level (segment_sum in JAX)

Partitioning matches the paper: time (crawl id) x domain-shard.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CrawlConfig:
    n_domains: int = 256
    n_pages_per_domain: int = 12
    n_seed: int = 64
    max_links: int = 24
    tokens_per_page: int = 128
    vocab: int = 4096


def _rng(crawl: str, shard: str, salt: int) -> np.random.RandomState:
    # hashlib, not hash(): Python string hashing is salted per process, and
    # asset outputs must be reproducible across workers (paper §3)
    import hashlib

    digest = hashlib.sha1(repr(("cc", crawl, shard, salt)).encode()).digest()
    return np.random.RandomState(int.from_bytes(digest[:4], "little") % (2**31))


def synth_crawl(crawl: str, shard: str, cfg: CrawlConfig) -> dict:
    """WARC-stub: page records (page_id, domain_id, out-link page ids, text)."""
    rng = _rng(crawl, shard, 0)
    n_pages = cfg.n_domains * cfg.n_pages_per_domain
    domain_of_page = np.repeat(np.arange(cfg.n_domains), cfg.n_pages_per_domain)
    # power-law-ish link targets: preferential attachment to low page ids
    n_links = rng.randint(1, cfg.max_links, size=n_pages)
    links = []
    for i in range(n_pages):
        raw = rng.pareto(1.5, size=n_links[i]) * 10
        tgt = (raw.astype(np.int64) * 131 + rng.randint(0, n_pages, n_links[i])) % n_pages
        links.append(tgt)
    text = rng.randint(0, cfg.vocab, size=(n_pages, cfg.tokens_per_page))
    return {
        "page_ids": np.arange(n_pages),
        "domain_of_page": domain_of_page,
        "links": links,
        "text": text.astype(np.int32),
    }


# ---------------------------------------------------------------------------
# The four assets (Figure 2)
# ---------------------------------------------------------------------------


def nodes_asset(crawl: str, shard: str, cfg: CrawlConfig) -> dict:
    """Seed-node extraction + preprocessing (dedupe, validity filter)."""
    rng = _rng(crawl, shard, 1)
    raw = rng.randint(0, cfg.n_domains * cfg.n_pages_per_domain,
                      size=cfg.n_seed * 2)
    seeds = np.unique(raw)[: cfg.n_seed]  # dedupe + cap, like URL cleaning
    return {"seed_pages": seeds.astype(np.int64)}


def edges_asset(crawl: str, shard: str, nodes: dict, cfg: CrawlConfig) -> dict:
    """HTML/link extraction from seed pages (the compute-heavy asset)."""
    pages = synth_crawl(crawl, shard, cfg)
    src, dst = [], []
    for pid in nodes["seed_pages"]:
        for tgt in pages["links"][int(pid)]:
            src.append(int(pid))
            dst.append(int(tgt))
    src_a = np.asarray(src, np.int64)
    dst_a = np.asarray(dst, np.int64)
    # text-derived edge weights (token-overlap score), batched in JAX — this
    # is the combined text+graph extraction the paper's pipeline customizes
    text = jnp.asarray(pages["text"])
    a = text[jnp.asarray(src_a)]
    b = text[jnp.asarray(dst_a)]
    weight = jnp.mean((a[:, :, None] == b[:, None, :]).any(axis=1)
                      .astype(jnp.float32), axis=-1)
    return {
        "src": src_a,
        "dst": dst_a,
        "weight": np.asarray(weight, np.float32),
        "domain_of_page": pages["domain_of_page"],
    }


def graph_asset(nodes: dict, edges: dict) -> dict:
    """Join nodes x edges -> deduplicated hyperlink graph."""
    pairs = edges["src"] * np.int64(1 << 32) + edges["dst"]
    uniq, inv = np.unique(pairs, return_inverse=True)
    w = np.zeros(len(uniq), np.float32)
    np.add.at(w, inv, edges["weight"])
    src = (uniq >> 32).astype(np.int64)
    dst = (uniq & ((1 << 32) - 1)).astype(np.int64)
    return {"src": src, "dst": dst, "weight": w,
            "domain_of_page": edges["domain_of_page"]}


def graph_aggr_asset(graph: dict, cfg: CrawlConfig) -> dict:
    """Aggregate the page graph to domain level (jax segment_sum)."""
    dom = jnp.asarray(graph["domain_of_page"])
    src_d = dom[jnp.asarray(graph["src"])]
    dst_d = dom[jnp.asarray(graph["dst"])]
    pair = src_d * cfg.n_domains + dst_d
    w = jax.ops.segment_sum(jnp.asarray(graph["weight"]), pair,
                            num_segments=cfg.n_domains * cfg.n_domains)
    nz = jnp.nonzero(w, size=min(w.size, 65536), fill_value=-1)[0]
    nz = np.asarray(nz)
    nz = nz[nz >= 0]
    w = np.asarray(w)
    return {
        "src_domain": (nz // cfg.n_domains).astype(np.int64),
        "dst_domain": (nz % cfg.n_domains).astype(np.int64),
        "weight": w[nz].astype(np.float32),
        "n_domains": cfg.n_domains,
    }


#: relative sizing of each asset's compute, calibrated to Table 1 durations
#: (edges dominates by ~2 orders of magnitude).
ASSET_COST_WEIGHTS = {"nodes": 0.4, "edges": 66.6, "graph": 0.9, "graph_aggr": 0.3}
