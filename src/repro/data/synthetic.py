"""Deterministic synthetic LM token pipeline.

Partition-aware (time x domain, matching the orchestrator's partitioning):
every (partition, step) pair maps to a unique, reproducible batch via a
counter-based hash — no state, so any worker can regenerate any shard after a
failure (the data-side half of fault tolerance).  The stream embeds learnable
n-gram structure (a position-mixed affine rule) so small-model training loss
decreases measurably in the examples.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _mix(x: np.ndarray) -> np.ndarray:
    # splitmix64 finalizer — counter-based, stateless (2^64 wraparound is
    # the point, so overflow warnings are silenced)
    with np.errstate(over="ignore"):
        x = (x ^ (x >> 30)) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> 27)) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> 31)


@dataclasses.dataclass(frozen=True)
class TokenDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    partition: str = "2024-01/all"
    structure: float = 0.85  # fraction of tokens that follow the learnable rule

    def _seed(self) -> np.uint64:
        import hashlib

        # stable across processes (hash() is salted): any worker regenerates
        # any shard identically after a failure
        digest = hashlib.sha1(
            repr(("repro-data", self.partition)).encode()).digest()
        return _mix(np.uint64(int.from_bytes(digest[:8], "little")))

    def batch(self, step: int) -> dict:
        b, s, v = self.global_batch, self.seq_len, self.vocab_size
        # partition-specific active vocabulary + successor-chain structure:
        # learnable within tens of steps by tiny models (support restriction
        # + "+1 within the chain"), yet distinct per partition.
        n_active = max(4, min(32, v // 4))
        rs_part = np.random.RandomState(int(self._seed() % np.uint64(2**31)))
        active = rs_part.choice(v, size=n_active, replace=False)
        rs = np.random.RandomState(
            int((self._seed() ^ _mix(np.uint64(step + 1))) % np.uint64(2**31)))
        idx = np.zeros((b, s + 1), np.int64)
        idx[:, 0] = rs.randint(0, n_active, b)
        gate = rs.rand(b, s + 1) < self.structure
        jumps = rs.randint(0, n_active, (b, s + 1))
        for t in range(1, s + 1):
            succ = (idx[:, t - 1] + 1) % n_active
            idx[:, t] = np.where(gate[:, t], succ, jumps[:, t])
        seq = active[idx]
        tokens = seq[:, :-1].astype(np.int32)
        targets = seq[:, 1:].astype(np.int32)
        weights = np.ones((b, s), np.float32)
        return {"tokens": tokens, "targets": targets, "weights": weights}

    def batches(self, start: int, n: int):
        for i in range(start, start + n):
            yield self.batch(i)
