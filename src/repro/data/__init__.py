from repro.data.synthetic import TokenDataset  # noqa: F401
from repro.data import commoncrawl  # noqa: F401
