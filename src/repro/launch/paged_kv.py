"""Paged KV cache: block-table-backed page pools shared across decode slots.

Dense serving allocates ``n_slots * max_len`` KV rows per layer up front, so
memory scales with the *worst case* of every slot simultaneously.  Here the
full-attention KV caches become fixed-size **page pools** shared by all
slots: a request reserves exactly ``ceil((prompt + max_new + 1) / page_size)``
pages at admission and returns them on completion, so hundreds of concurrent
streams fit in the memory a handful of dense slots would take — occupancy is
ragged *and* exact.

Layout
------
Per full-attention layer the pool leaves are ``k``/``v``:
``(n_pages, page_size, H, D)`` and ``pos``: ``(n_pages, page_size)`` (−1 =
empty).  A device-resident **block table** ``(n_slots, max_pages)`` maps each
slot's logical pages to physical ones; unallocated entries hold ``n_pages``
(one past the pool), which JAX scatter drops and ``jnp.take(mode="fill")``
masks — no branching anywhere on the device path.

Only full-attention layers page.  SWA rings are O(window), MLA latents are
~7% of expanded KV, cross caches are O(enc_len) and recurrent states are
O(1) per slot; those stay slot-dense ("hybrid paging"), and the cache tree
mixes both kinds transparently.

Correctness invariants (each one guards a real aliasing bug):

* newly allocated pages get their pool ``pos`` reset to −1 *before* use —
  a recycled page's stale positions could otherwise unmask another
  request's keys;
* a freed slot's table row is cleared to ``n_pages`` immediately, so decode
  ticks for dead slots scatter out of bounds instead of into recycled pages;
* the dense per-slot leaves (rings/latents/states) are reset to their
  ``init_cache`` values in the same fused jit at allocation time.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import LanguageModel
from repro.models.model import _is_spec_leaf


def _pages_dim(spec_leaf) -> int | None:
    axes = spec_leaf[1]
    return axes.index("pages") if "pages" in axes else None


def _batch_dim(spec_leaf) -> int:
    return spec_leaf[1].index("batch")


@dataclasses.dataclass
class PageStats:
    n_pages: int
    page_size: int
    pages_in_use: int
    pages_free: int
    tokens_reserved: int

    @property
    def utilization(self) -> float:
        return self.pages_in_use / max(self.n_pages, 1)


class PagedKVCache:
    """Host-side allocator + device-side gather/scatter for the hybrid cache.

    ``max_pages`` bounds one slot's capacity: the dense *view* used during
    chunked prefill is ``max_pages * page_size`` tokens long, and position
    ``p`` of a slot always lives at page ``p // page_size`` of its table row
    — the gathered view is literally a dense cache, so ``prefill_chunk``
    needs no paged-awareness at all.
    """

    def __init__(self, model: LanguageModel, n_slots: int, n_pages: int,
                 page_size: int, max_pages: int, enc_len: int = 0,
                 dtype=jnp.bfloat16):
        self.model = model
        self.n_slots = n_slots
        self.n_pages = n_pages
        self.page_size = page_size
        self.max_pages = max_pages
        self.view_len = max_pages * page_size
        pages = (n_pages, page_size)
        self.specs = model.cache_specs(n_slots, self.view_len, enc_len=enc_len,
                                       dtype=dtype, pages=pages)
        self.view_specs = model.cache_specs(1, self.view_len, enc_len=enc_len,
                                            dtype=dtype, pages=None)
        self.cache = model.init_cache(n_slots, self.view_len, enc_len=enc_len,
                                      dtype=dtype, pages=pages)
        self.table = jnp.full((n_slots, max_pages), n_pages, jnp.int32)
        self._free = list(range(n_pages - 1, -1, -1))  # pop() -> page 0 first
        self._slot_pages: list[list[int]] = [[] for _ in range(n_slots)]

        self._gather = jax.jit(self._gather_impl)
        # scatter/prepare rebuild the whole cache tree from the old one plus
        # a small update; donating the old tree makes them in-place writes
        self._scatter = jax.jit(self._scatter_impl, donate_argnums=(0,))
        self._prepare = jax.jit(self._prepare_impl, donate_argnums=(0,))

    # ------------------------------------------------------------ allocation
    def pages_needed(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.page_size)

    def can_alloc(self, n_tokens: int) -> bool:
        need = self.pages_needed(n_tokens)
        return need <= self.max_pages and need <= len(self._free)

    def alloc(self, slot: int, n_tokens: int) -> bool:
        """Reserve capacity for ``n_tokens`` in ``slot`` and reset its state
        (pool positions of the new pages + the dense per-slot leaves)."""
        if self._slot_pages[slot]:
            raise ValueError(f"slot {slot} already allocated")
        need = self.pages_needed(n_tokens)
        if need > self.max_pages or need > len(self._free):
            return False
        pages = [self._free.pop() for _ in range(need)]
        self._slot_pages[slot] = pages
        row = pages + [self.n_pages] * (self.max_pages - need)
        row = jnp.asarray(row, jnp.int32)
        self.table = self.table.at[slot].set(row)
        self.cache = self._prepare(self.cache, row,
                                   jnp.asarray(slot, jnp.int32))
        return True

    def free(self, slot: int) -> None:
        self._free.extend(reversed(self._slot_pages[slot]))
        self._slot_pages[slot] = []
        self.table = self.table.at[slot].set(self.n_pages)

    def stats(self) -> PageStats:
        used = sum(len(p) for p in self._slot_pages)
        return PageStats(
            n_pages=self.n_pages, page_size=self.page_size,
            pages_in_use=used, pages_free=len(self._free),
            tokens_reserved=used * self.page_size)

    # ------------------------------------------------------- device gather/scatter
    def gather_slot(self, slot: int):
        """Dense (B=1, view_len, ...) cache view of one slot — the exact tree
        ``init_cache(1, view_len)`` would produce, for ``prefill_chunk``."""
        return self._gather(self.cache, self.table[slot][None],
                            jnp.asarray([slot], jnp.int32))

    def scatter_slot(self, slot: int, view: Any) -> None:
        self.cache = self._scatter(self.cache, view, self.table[slot][None],
                                   jnp.asarray([slot], jnp.int32))

    def _gather_impl(self, cache, rows, slots):
        """Dense (G, view_len, ...) view of G slots at once (``rows``:
        ``(G, max_pages)``, ``slots``: ``(G,)``).  Padded group members use
        ``slots == n_slots`` / ``rows == n_pages``: their view fills with
        init values and their scatter-back is dropped, so a fixed group size
        costs one jit trace per chunk length."""
        G = slots.shape[0]

        def g(leaf, spec):
            fill = -1 if leaf.dtype == jnp.int32 else 0
            pdim = _pages_dim(spec)
            if pdim is None:
                bdim = _batch_dim(spec)
                return jnp.take(leaf, slots, axis=bdim, mode="fill",
                                fill_value=fill)
            v = jnp.take(leaf, rows.reshape(-1), axis=pdim, mode="fill",
                         fill_value=fill)
            shp = (v.shape[:pdim] + (G, self.max_pages * self.page_size)
                   + v.shape[pdim + 2:])
            return v.reshape(shp)

        return jax.tree.map(g, cache, self.specs, is_leaf=_is_spec_leaf)

    def _scatter_impl(self, cache, view, rows, slots):
        G = slots.shape[0]

        def s(leaf, v, spec):
            pdim = _pages_dim(spec)
            if pdim is None:
                bdim = _batch_dim(spec)
                # padded entries == n_slots: out of bounds -> dropped
                idx = (slice(None),) * bdim + (slots,)
                return leaf.at[idx].set(v.astype(leaf.dtype))
            v = v.reshape(v.shape[:pdim]
                          + (G * self.max_pages, self.page_size)
                          + v.shape[pdim + 2:])
            idx = (slice(None),) * pdim + (rows.reshape(-1),)
            # unallocated row entries == n_pages: out of bounds -> dropped
            return leaf.at[idx].set(v.astype(leaf.dtype))

        return jax.tree.map(s, cache, view, self.specs, is_leaf=_is_spec_leaf)

    def _prepare_impl(self, cache, row, slot):
        """Fused allocation-time reset: pool ``pos`` of the new pages -> −1
        (kills stale positions on recycled pages) and the slot's dense leaves
        back to their init values."""
        def r(leaf, spec):
            pdim = _pages_dim(spec)
            if pdim is not None:
                if leaf.dtype != jnp.int32:
                    return leaf  # k/v garbage is masked by pos == -1
                idx = (slice(None),) * pdim + (row,)
                return leaf.at[idx].set(-1)
            bdim = _batch_dim(spec)
            idx = (slice(None),) * bdim + (slot,)
            fill = -1 if leaf.dtype == jnp.int32 else 0
            return leaf.at[idx].set(fill)

        return jax.tree.map(r, cache, self.specs, is_leaf=_is_spec_leaf)


@functools.cache
def chunk_ladder(chunk_max: int) -> tuple[int, ...]:
    """Power-of-two chunk sizes {1, 2, 4, ..., chunk_max} — every prompt
    length decomposes exactly (greedy largest-first), so chunked prefill
    needs zero padding and the jit trace count is bounded by the ladder."""
    if chunk_max < 1 or chunk_max & (chunk_max - 1):
        raise ValueError(f"chunk_max must be a power of two, got {chunk_max}")
    out = []
    c = chunk_max
    while c >= 1:
        out.append(c)
        c //= 2
    return tuple(out)


def decompose(n: int, chunk_max: int) -> list[int]:
    """Exact chunk decomposition of ``n`` tokens, largest chunks first."""
    out = []
    for c in chunk_ladder(chunk_max):
        while n >= c:
            out.append(c)
            n -= c
    return out
