"""Cost-model-routed multi-replica serving front-end.

The paper's thesis — price work against heterogeneous platforms with a
transparent cost model instead of defaulting to one PaaS — applied to
inference replicas: latency-SLO traffic goes to premium capacity (faster,
reliable, expensive), bulk traffic to spot (cheap, preemptible).  The router
reuses the batch stack wholesale:

* ``CostEstimate`` + ``CostModel.expected_cost_with_retries`` /
  ``schedule_duration`` price each request per replica, retries and rework
  included — the same math the batch planner loads onto its timeline;
* ``OnlineCostModel.observe``/``duration_ratio`` close the loop: realized
  service times recalibrate per-(class, platform) duration predictions with
  the hierarchical EWMAs from PR 8;
* per-replica ``CircuitBreaker``s (closed → open → half-open probe) stop
  routing to replicas that are hard-failing, with a single probe after
  cooldown.

A request is priced as service time = work_tokens / (tokens_per_s ·
perf_factor("serve")) scaled by the learned duration ratio, plus the
replica's current backlog delay.  Deadline feasibility uses
``schedule_duration`` (rework-aware wall-clock); cost uses
``expected_cost_with_retries`` (failures burn money).
"""
from __future__ import annotations

import dataclasses

from repro.core.adaptive import CircuitBreaker, OnlineCostModel
from repro.core.costmodel import CostEstimate, CostModel
from repro.core.platforms import Platform, default_catalog


@dataclasses.dataclass(frozen=True)
class ServeClass:
    """Request class for pricing purposes (the 'asset' key of the EWMAs)."""
    name: str
    deadline_s: float | None  # None = bulk (throughput, min cost)

    @property
    def is_slo(self) -> bool:
        return self.deadline_s is not None


@dataclasses.dataclass
class Replica:
    """One serving replica pinned to a platform from the catalog."""
    name: str
    platform: Platform
    tokens_per_s: float  # base service rate at perf_factor 1.0
    backlog_tokens: float = 0.0

    def rate(self) -> float:
        return self.tokens_per_s * self.platform.perf_factor("serve")


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    rid: int
    replica: str
    cls: str
    estimate: CostEstimate
    expected_usd: float
    expected_wall_s: float
    deadline_feasible: bool


class ReplicaRouter:
    """Price every request against every live replica; route SLO traffic to
    the cheapest deadline-feasible replica (fastest if none is feasible) and
    bulk traffic to the cheapest overall."""

    def __init__(self, replicas: list[Replica],
                 model: OnlineCostModel | CostModel | None = None,
                 breaker_failures: int = 3, breaker_cooldown_s: float = 30.0):
        if not replicas:
            raise ValueError("need at least one replica")
        self.replicas = {r.name: r for r in replicas}
        if len(self.replicas) != len(replicas):
            raise ValueError("duplicate replica names")
        self.model = model if model is not None else OnlineCostModel()
        self.breakers = {
            r.name: CircuitBreaker(r.platform.name,
                                   failures=breaker_failures,
                                   cooldown_s=breaker_cooldown_s)
            for r in replicas
        }
        self._inflight: dict[int, RouteDecision] = {}
        self.counters = {"routed": 0, "slo_to_premium": 0, "slo_total": 0,
                         "bulk_total": 0, "slo_infeasible": 0,
                         "breaker_denials": 0, "unroutable": 0}

    # -------------------------------------------------------------- pricing
    def price(self, work_tokens: int, cls: ServeClass,
              replica: Replica) -> CostEstimate:
        """Serve-time CostEstimate for one request on one replica.

        ``compute_s`` is the pure service time (what the request is billed
        for); ``duration_s`` adds the replica's backlog delay (what the
        deadline check sees).  The learned duration ratio recalibrates the
        catalog service rate per (class, platform) cell.
        """
        plat = replica.platform
        serve_s = work_tokens / max(replica.rate(), 1e-9)
        if isinstance(self.model, OnlineCostModel):
            serve_s *= self.model.duration_ratio(cls.name, plat.name)
        wait_s = replica.backlog_tokens / max(replica.rate(), 1e-9)
        hours = serve_s / 3600.0
        base = hours * plat.chips * plat.chip_hour_usd
        surcharge = base * plat.surcharge_rate
        storage = hours * plat.chips * plat.storage_usd_per_chip_hour
        return CostEstimate(platform=plat.name, duration_s=wait_s + serve_s,
                            compute_s=serve_s, base_usd=base,
                            surcharge_usd=surcharge, storage_usd=storage)

    # -------------------------------------------------------------- routing
    def route(self, rid: int, work_tokens: int, cls: ServeClass,
              now: float = 0.0) -> RouteDecision | None:
        """Pick a replica for one request; returns None when every breaker
        is open (caller should queue and retry after the cooldown)."""
        live = [r for r in self.replicas.values()
                if self.breakers[r.name].allow(now)]
        denied = len(self.replicas) - len(live)
        self.counters["breaker_denials"] += denied
        if not live:
            self.counters["unroutable"] += 1
            return None

        scored = []
        for r in live:
            est = self.price(work_tokens, cls, r)
            usd = self.model.expected_cost_with_retries(est, r.platform,
                                                        cls.name)
            wall = self.model.schedule_duration(est, r.platform, cls.name)
            feasible = (cls.deadline_s is None or wall <= cls.deadline_s)
            scored.append((r, est, usd, wall, feasible))

        if cls.is_slo:
            self.counters["slo_total"] += 1
            feas = [s for s in scored if s[4]]
            if feas:
                r, est, usd, wall, ok = min(
                    feas, key=lambda s: (s[2], s[3], s[0].name))
            else:  # degraded: nothing meets the deadline, take the fastest
                self.counters["slo_infeasible"] += 1
                r, est, usd, wall, ok = min(
                    scored, key=lambda s: (s[3], s[2], s[0].name))
            if r.platform.kind == "premium":
                self.counters["slo_to_premium"] += 1
        else:
            self.counters["bulk_total"] += 1
            r, est, usd, wall, ok = min(
                scored, key=lambda s: (s[2], s[3], s[0].name))

        self.breakers[r.name].note_launch(now)
        r.backlog_tokens += work_tokens
        decision = RouteDecision(rid=rid, replica=r.name, cls=cls.name,
                                 estimate=est, expected_usd=usd,
                                 expected_wall_s=wall, deadline_feasible=ok)
        self._inflight[rid] = decision
        self.counters["routed"] += 1
        return decision

    def complete(self, rid: int, outcome: str, realized_s: float,
                 now: float = 0.0) -> None:
        """Fold a finished request back into the online model + breaker.
        ``outcome`` ∈ {success, failure, preemption, cancelled}."""
        d = self._inflight.pop(rid, None)
        if d is None:
            raise KeyError(f"unknown request {rid}")
        r = self.replicas[d.replica]
        r.backlog_tokens = max(
            0.0, r.backlog_tokens - d.estimate.compute_s * r.rate())
        if isinstance(self.model, OnlineCostModel):
            self.model.observe(d.cls, r.platform.name, outcome,
                               predicted_s=d.estimate.compute_s,
                               realized_s=realized_s)
        self.breakers[d.replica].record(outcome, now)

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        out = dict(self.counters)
        out["replicas"] = {
            name: {"platform": r.platform.name,
                   "backlog_tokens": r.backlog_tokens,
                   "breaker": self.breakers[name].state,
                   "trips": self.breakers[name].trips}
            for name, r in self.replicas.items()
        }
        return out


def default_replicas(tokens_per_s: float = 2000.0) -> list[Replica]:
    """A premium + spot pair from the default catalog (the Table-1 economics
    the batch planner prices against), for tests and the benchmark."""
    cat = default_catalog()
    return [
        Replica(name="premium-0", platform=cat["pod-premium"],
                tokens_per_s=tokens_per_s),
        Replica(name="spot-0", platform=cat["pod-spot"],
                tokens_per_s=tokens_per_s),
        Replica(name="spot-1", platform=cat["pod-spot"],
                tokens_per_s=tokens_per_s),
    ]
