"""Analytic per-step FLOPs and HBM-byte models for the roofline.

Why analytic: XLA's HLO cost analysis counts while-loop bodies once, so a
scan-over-layers program under-reports FLOPs/bytes by ~n_layers x (verified
against the compiled HLO; see EXPERIMENTS.md §Roofline methodology).  The
collective term, by contrast, IS taken from the compiled HLO with loop
trip-count scaling (analysis.collective_bytes).

Conventions:
  * matmul (m,k)x(k,n): 2mkn flops.
  * causal attention effective kv length: S/2 (the TPU flash kernel skips
    fully-masked tiles); sliding window: min(window, S/2-ish) -> window.
  * train = fwd * (3 + 1 if full remat): bwd = 2x fwd, remat adds one fwd.
  * HBM bytes: local weight shards (f32 train state traffic, bf16 compute
    reads), FSDP-gathered per-layer weights (1/TP per device), activation
    residual/intermediate traffic, KV-cache reads for decode.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeSpec

_ACT_RT_COEFF_TRAIN = 30.0  # residual+norm+proj intermediates, rw, remat
_ACT_RT_COEFF_FWD = 12.0


def _attn_flops(cfg: ModelConfig, t: float, kv_eff: float,
                decode: bool = False) -> float:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2 * t * d * (2 * hq * dh + 2 * hkv * dh)  # q,o + k,v
    core = 2 * t * kv_eff * hq * dh * 2  # qk^T + pv
    return proj + core


def _mla_flops(cfg: ModelConfig, t: float, kv_eff: float,
               decode: bool = False) -> float:
    d, h = cfg.d_model, cfg.n_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    f = 0.0
    if ql:
        f += 2 * t * d * ql + 2 * t * ql * h * (nope + rope)
    else:
        f += 2 * t * d * h * (nope + rope)
    f += 2 * t * d * (kl + rope)  # kv down-projection + shared rope key
    if decode:
        # absorbed path: scores/outputs live in latent space
        f += 2 * t * h * nope * kl  # q absorb
        f += 2 * t * kv_eff * h * (kl + rope)  # scores vs latent cache
        f += 2 * t * kv_eff * h * kl  # attention-weighted latents
        f += 2 * t * h * kl * vd  # output absorb
    else:
        f += 2 * t * kl * h * (nope + vd)  # expand k_nope, v
        f += 2 * t * kv_eff * h * (nope + rope) + 2 * t * kv_eff * h * vd
    f += 2 * t * h * vd * d  # o-proj
    return f


def _mlp_flops(cfg: ModelConfig, t: float) -> float:
    mult = 3 if cfg.mlp_type == "glu" else 2
    return 2 * t * cfg.d_model * cfg.d_ff * mult


def _moe_flops(cfg: ModelConfig, t: float) -> float:
    d, ffe = cfg.d_model, cfg.d_ff_expert
    f = 2 * t * d * cfg.n_experts  # router
    f += 2 * t * cfg.top_k * d * ffe * 3  # routed experts (glu)
    f += 2 * t * d * (cfg.n_shared_experts * ffe) * 3  # shared experts
    return f


def _rglru_flops(cfg: ModelConfig, t: float) -> float:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    f = 2 * t * d * w * 2  # two input branches
    f += 2 * t * w * w * 2  # recurrence + input gates
    f += 2 * t * w * cfg.conv_width  # depthwise conv
    f += 10 * t * w  # scan update arithmetic
    f += 2 * t * w * d  # out proj
    return f


def _rwkv_flops(cfg: ModelConfig, t: float) -> float:
    d = cfg.d_model
    n = cfg.rwkv_head_size
    h = d // n
    f = 2 * t * d * d * 4  # r,k,v,g projections
    f += 2 * t * d * cfg.rwkv_decay_lora * 2  # decay lora
    f += 2 * t * d * 5 * cfg.rwkv_mix_lora * 2  # ddlerp loras
    f += t * h * n * n * 6  # wkv state update + readout per token
    f += 2 * t * d * cfg.d_ff * 2 + 2 * t * d * d  # channel mix
    f += 2 * t * d * d  # o-proj
    return f


def fwd_flops(cfg: ModelConfig, tokens: float, kv_len: float,
              decode: bool = False) -> float:
    """Forward FLOPs for `tokens` processed tokens against kv_len context."""
    total = 0.0
    kinds = cfg.layer_types()
    if cfg.enc_dec:
        kinds = ["attn"] * cfg.n_enc_layers + ["xattn"] * cfg.n_layers
    for i, kind in enumerate(kinds):
        if kind in ("attn", "xattn"):
            kv_eff = kv_len if decode else kv_len / 2
            if cfg.use_mla:
                total += _mla_flops(cfg, tokens, kv_eff, decode)
            else:
                total += _attn_flops(cfg, tokens, kv_eff, decode)
            if kind == "xattn":  # cross-attention (bidirectional)
                total += _attn_flops(cfg, tokens, kv_len, decode)
        elif kind == "swa":
            kv_eff = min(cfg.window, kv_len) if cfg.window else kv_len
            if not decode:
                kv_eff = min(kv_eff, kv_len / 2)
            total += _attn_flops(cfg, tokens, kv_eff, decode)
        elif kind == "rglru":
            total += _rglru_flops(cfg, tokens)
        elif kind == "rwkv6":
            total += _rwkv_flops(cfg, tokens)
            continue  # rwkv block includes its channel mix
        # mlp / moe
        is_moe = (cfg.n_experts > 0 and i >= cfg.first_dense_layers
                  and kind in ("attn", "swa"))
        if is_moe:
            total += _moe_flops(cfg, tokens)
        else:
            total += _mlp_flops(cfg, tokens)
    total += 2 * tokens * cfg.d_model * cfg.vocab_size  # lm head
    return total


def step_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        mult = 4.0 if cfg.remat == "full" else 3.0
        return mult * fwd_flops(cfg, b * s, s)
    if shape.kind == "prefill":
        return fwd_flops(cfg, b * s, s)
    return fwd_flops(cfg, float(b), float(s), decode=True)


# ---------------------------------------------------------------------------
# HBM byte model (per device)
# ---------------------------------------------------------------------------


def _cache_bytes_total(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Total KV-cache / state bytes across the whole job (bf16 cache)."""
    b, s = shape.global_batch, shape.seq_len
    total = 0.0
    kinds = cfg.layer_types()
    if cfg.enc_dec:
        kinds = ["xattn"] * cfg.n_layers
    for kind in kinds:
        if kind in ("attn", "xattn"):
            if cfg.use_mla:
                per_tok = cfg.kv_lora_rank + cfg.qk_rope_head_dim
            else:
                per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
            total += b * s * per_tok * 2
            if kind == "xattn":  # cross K/V cache
                total += b * s * 2 * cfg.n_kv_heads * cfg.head_dim * 2
        elif kind == "swa":
            w = min(cfg.window or s, s)
            total += b * w * 2 * cfg.n_kv_heads * cfg.head_dim * 2
        elif kind == "rglru":
            total += b * (cfg.lru_width or cfg.d_model) * 4
        elif kind == "rwkv6":
            n = cfg.rwkv_head_size
            total += b * (cfg.d_model // n) * n * n * 4
    return total


def step_hbm_bytes(cfg: ModelConfig, shape: ShapeSpec, n_chips: int,
                   tp: int) -> float:
    """Per-device HBM traffic per step (documented estimate, DESIGN.md §5)."""
    p = cfg.param_count()
    b, s = shape.global_batch, shape.seq_len
    layers = cfg.n_layers + (cfg.n_enc_layers if cfg.enc_dec else 0)
    if shape.kind == "train":
        local_state = p / n_chips * (12 + 8 + 16 + 4)  # f32 reads, grads, opt
        gathered = p / max(tp, 1) * 2 * 3 * 2  # bf16 layer gathers, 3 passes
        acts = (b * s / n_chips) * cfg.d_model * layers * \
            _ACT_RT_COEFF_TRAIN * 2
        return local_state + gathered + acts
    if shape.kind == "prefill":
        weights = p / max(tp, 1) * 2
        acts = (b * s / n_chips) * cfg.d_model * layers * _ACT_RT_COEFF_FWD * 2
        cache_w = _cache_bytes_total(cfg, shape) / n_chips
        return weights + acts + cache_w
    # decode: weights + full cache read per step
    weights = p / max(tp, 1) * 2
    cache_r = _cache_bytes_total(cfg, shape) / n_chips
    return weights + cache_r
