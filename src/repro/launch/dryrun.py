"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract memory/cost/collective analysis (assignment §e/§g).

MUST set XLA_FLAGS before any other import — jax locks the device count on
first backend init.  Do NOT set this globally: smoke tests and benches see
one device.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, applicable_shapes, get_config, list_configs  # noqa: E402
from repro.configs.base import ModelConfig, ShapeSpec  # noqa: E402
from repro.distributed.sharding import MeshInfo, use_mesh_info  # noqa: E402
from repro.launch import analysis, flops as flops_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import (batch_specs, cache_input_specs,  # noqa: E402
                                decode_token_specs, param_specs)
from repro.models import LanguageModel  # noqa: E402
from repro.optim import AdamW, OptConfig  # noqa: E402


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Assignment formula: 6*N*D train (N_active for MoE); 2*N*D inference."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def active_param_count(cfg: ModelConfig) -> int:
    if cfg.n_experts == 0:
        return cfg.param_count()
    n_moe_layers = sum(
        1 for i, t in enumerate(cfg.layer_types())
        if cfg.n_experts > 0 and i >= cfg.first_dense_layers
        and t in ("attn", "swa"))
    routed = cfg.n_experts * 3 * cfg.d_model * cfg.d_ff_expert
    active = cfg.top_k * 3 * cfg.d_model * cfg.d_ff_expert
    return cfg.param_count() - n_moe_layers * (routed - active)


def make_train_step(model: LanguageModel, opt: AdamW, param_shardings=None):
    def train_step(params, opt_state, batch):
        grad_fn = jax.value_and_grad(model.train_loss, has_aux=True)
        (_, metrics), grads = grad_fn(params, batch)
        if param_shardings is not None:
            # pin gradient shardings to the FSDP layout so XLA lowers the
            # data-axis reduction as reduce-scatter, not full all-reduce
            # (EXPERIMENTS.md §Perf iteration 2)
            grads = jax.lax.with_sharding_constraint(grads, param_shardings)
        new_params, new_state, stats = opt.update(grads, opt_state, params)
        return new_params, new_state, {**metrics, **stats}

    return train_step


def shardings_of(tree):
    return jax.tree.map(lambda s: s.sharding, tree)


def build_lowered(cfg: ModelConfig, shape: ShapeSpec, info: MeshInfo):
    """Returns (lowered, n_args_tree) for the right step fn of the cell."""
    if shape.kind == "train":
        model = LanguageModel(cfg)
        opt = AdamW(OptConfig())
        psds = param_specs(model, info)
        osds = _opt_specs(model, opt, info, psds)
        bsds = batch_specs(cfg, shape, info)
        fn = jax.jit(
            make_train_step(model, opt, shardings_of(psds)),
            out_shardings=(shardings_of(psds), shardings_of(osds), None),
            donate_argnums=(0, 1),
        )
        return fn.lower(psds, osds, bsds), (psds, osds, bsds)

    # serving cells carry bf16 weights (no optimizer states)
    serve_cfg = cfg.scaled(param_dtype="bfloat16")
    model = LanguageModel(serve_cfg)
    psds = param_specs(model, info)
    csds = cache_input_specs(model, shape, info)
    if shape.kind == "prefill":
        bsds = batch_specs(serve_cfg, shape, info)
        fn = jax.jit(model.prefill, donate_argnums=(2,))
        return fn.lower(psds, bsds, csds), (psds, bsds, csds)

    tsds, possds = decode_token_specs(serve_cfg, shape, info)
    fn = jax.jit(model.decode_step, donate_argnums=(2,),
                 out_shardings=(None, shardings_of(csds)))
    return fn.lower(psds, tsds, csds, possds), (psds, tsds, csds, possds)


def _opt_specs(model, opt, info, psds):
    shapes = jax.eval_shape(opt.init, model.abstract_params())
    axes = model.param_axes

    def attach(sds, ax):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=info.sharding(sds.shape, ax))

    out = {
        "m": jax.tree.map(attach, shapes["m"], axes,
                          is_leaf=lambda x: isinstance(x, tuple)
                          and all(isinstance(e, (str, type(None))) for e in x)),
        "v": jax.tree.map(attach, shapes["v"], axes,
                          is_leaf=lambda x: isinstance(x, tuple)
                          and all(isinstance(e, (str, type(None))) for e in x)),
        "step": jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=info.sharding((), ())),
    }
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             keep_hlo: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "kind": shape.kind}

    if shape.name == "long_500k" and not cfg.is_subquadratic:
        cell["status"] = "skipped"
        cell["reason"] = "full-attention arch: long_500k skipped per assignment"
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    info = MeshInfo(mesh)
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        with use_mesh_info(info), mesh:
            lowered, _args = build_lowered(cfg, shape, info)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = analysis.safe_memory_analysis(compiled)
            print(f"[{arch} x {shape_name} x {mesh_name}] memory_analysis:",
                  {k: f"{v/2**30:.2f}GiB" for k, v in mem.items()
                   if "bytes" in k})
            ca = analysis.safe_cost_analysis(compiled)
            print(f"[{arch} x {shape_name} x {mesh_name}] cost_analysis: "
                  f"flops={ca.get('flops', 0):.3e} "
                  f"bytes={ca.get('bytes accessed', 0):.3e}")
            hlo = compiled.as_text()
            coll = analysis.collective_bytes(hlo)

        mf = model_flops(cfg, shape)
        # analytic compute/memory terms: XLA cost analysis counts while-loop
        # (scan) bodies once, so HLO-reported flops/bytes under-count by
        # ~n_layers x; the collective term comes from the compiled HLO with
        # trip-count scaling (see launch/flops.py + analysis.py docstrings).
        tp = 16  # model-axis size on both assigned meshes
        step_cfg = cfg if shape.kind == "train" else \
            cfg.scaled(param_dtype="bfloat16")
        hlo_flops = flops_mod.step_flops(step_cfg, shape) / n_chips
        hbm_bytes = flops_mod.step_hbm_bytes(step_cfg, shape, n_chips, tp)
        roof = analysis.roofline(
            flops_per_device=hlo_flops,
            hbm_bytes_per_device=hbm_bytes,
            coll_bytes_per_device=coll["total"],
            model_flops_total=mf,
            n_chips=n_chips,
        )
        cell.update({
            "status": "ok",
            "n_chips": n_chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory_analysis": mem,
            "cost_analysis_raw": {k: v for k, v in ca.items()
                                  if not k.startswith("_")},
            "analytic_flops_per_device": hlo_flops,
            "analytic_hbm_bytes_per_device": hbm_bytes,
            "collective_bytes": {k: v for k, v in coll.items()
                                 if k != "op_counts"},
            "collective_ops": coll["op_counts"],
            "model_flops": mf,
            "params_total": cfg.param_count(),
            "params_active": active_param_count(cfg),
            "roofline": roof,
        })
        if keep_hlo:
            cell["hlo_len"] = len(hlo)
    except Exception as e:
        cell["status"] = "error"
        cell["error"] = f"{type(e).__name__}: {e}"
        cell["traceback"] = traceback.format_exc()[-4000:]
    return cell


def plan_preview(objective_name: str, time_value: float,
                 budget_usd: float | None, deadline_h: float | None,
                 plan_rows: int = 50, select: str | None = None,
                 adaptive: bool = False,
                 drift: "list[str] | None" = None) -> None:
    """Orchestration dry-run: global planner assignment for the paper's
    Common-Crawl pipeline, printed as a per-task table (truncated past
    ``plan_rows`` tasks with a per-asset/platform summary) with predicted
    cost, slot contention and makespan vs the greedy per-task factory — no
    jax work involved.  ``select`` is an asset-selection expression (e.g.
    ``"cc_fetch+"`` for an asset plus its downstream cone, ``"tag:k=v"``,
    ``"*"``) parsed by ``repro.core.selection.AssetSelection.parse`` — the
    same surface ``RunCoordinator.plan()/materialize()`` accept.

    ``adaptive`` previews the closed-loop planner: pricing goes through an
    ``OnlineCostModel`` and scheduling is preemption-aware (each task's
    timeline slot inflated by expected retry rework on its platform).
    ``drift`` entries of the form ``asset@platform=ratio`` seed the online
    model with assumed realized/predicted duration ratios — "what would the
    plan look like if cc_edges ran 3x slow on pod-spot?"."""
    from repro.core import (AssetSelection, CostModel, DynamicClientFactory,
                            Objective, OnlineCostModel, RunPlanner,
                            SlotConfig, default_catalog)

    try:
        from benchmarks.cc_pipeline import SMALL, build_graph
        graph, default_sel = build_graph(partitions=SMALL), "graph_aggr"
    except ImportError:  # installed as a package without the benchmarks dir
        from repro.core import AssetGraph, ComputeProfile, asset
        a = asset(name="extract",
                  compute=ComputeProfile(work_chip_hours=200.0,
                                         speedup_class="scan"))(lambda ctx: 0)
        b = asset(name="transform", deps=("extract",),
                  compute=ComputeProfile(work_chip_hours=26.0,
                                         speedup_class="shuffle"))(
                      lambda ctx, extract: 0)
        graph, default_sel = AssetGraph([a, b]), "transform"
    selection = AssetSelection.parse(select or default_sel)

    objective = {
        "min_cost": Objective.min_cost,
        "min_time": Objective.min_time,
        "balanced": lambda: Objective.balanced(time_value),
    }[objective_name]().constrained(budget_usd=budget_usd,
                                    deadline_s=None if deadline_h is None
                                    else deadline_h * 3600.0)
    cost_model = CostModel()
    if adaptive or drift:
        online = OnlineCostModel(base=cost_model)
        for spec_str in drift or []:
            # asset@platform=ratio, e.g. cc_edges@pod-spot=3.0 — seed the
            # EWMA well past min_observations so the ratio dominates
            lhs, _, ratio = spec_str.partition("=")
            a, _, p = lhs.partition("@")
            if not (a and p and ratio):
                raise SystemExit(f"bad --drift {spec_str!r} "
                                 f"(want asset@platform=ratio)")
            for _ in range(8):
                online.observe(a, p, "success", predicted_s=1.0,
                               realized_s=float(ratio))
        cost_model = online
    factory = DynamicClientFactory(default_catalog(), cost_model, objective)
    # the default SlotConfig matches RunCoordinator's execution limits, so
    # the previewed makespan accounts for finite per-platform slots
    plan = RunPlanner(graph, factory, slots=SlotConfig(),
                      preemption_aware=adaptive or bool(drift)).plan(selection)
    mode = " adaptive" if adaptive or drift else ""
    print(f"run plan ({objective.name}{mode}, "
          f"select={select or default_sel!r}, "
          f"{len(plan.choices)} tasks, {plan.iterations} iterations):")
    print(plan.table(max_rows=plan_rows))


def resume_preview(journal_dir: str, run_id: str) -> None:
    """Crash-recovery dry-run: replay a run journal (torn-tail tolerant)
    and print what ``RunCoordinator.resume`` would do — landed work,
    money already spent, and the in-flight frontier it would re-launch —
    without executing anything."""
    from repro.core import JournalState, RunJournal

    if not RunJournal.exists(journal_dir, run_id):
        raise SystemExit(f"no journal for run {run_id!r} in {journal_dir}")
    records, dropped = RunJournal.load(journal_dir, run_id)
    state = JournalState.from_records(records, dropped)
    print(state.summary())
    if state.ended and state.ok:
        print("run ended ok: nothing to resume")
    else:
        print(f"resume would re-launch {len(state.frontier())} frontier "
              f"task(s) and carry {len(state.succeeded)} landed "
              f"materialization(s)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--plan", action="store_true",
                    help="print the DAG-level run-plan preview and exit")
    ap.add_argument("--objective", default="balanced",
                    choices=["min_cost", "min_time", "balanced"])
    ap.add_argument("--time-value", type=float, default=60.0,
                    help="USD/hour of wall-clock (balanced objective)")
    ap.add_argument("--budget-usd", type=float, default=None)
    ap.add_argument("--deadline-h", type=float, default=None)
    ap.add_argument("--plan-rows", type=int, default=50,
                    help="max per-task rows in the --plan table before "
                         "truncating to a per-asset/platform summary")
    ap.add_argument("--select", default=None,
                    help="asset selection for --plan, e.g. 'cc_fetch+' "
                         "(asset + downstream cone), '+graph_aggr', "
                         "'tag:stage=ingest', '*'")
    ap.add_argument("--adaptive", action="store_true",
                    help="with --plan: preview the closed-loop planner "
                         "(online cost model + preemption-aware schedule)")
    ap.add_argument("--drift", action="append", default=None,
                    metavar="ASSET@PLATFORM=RATIO",
                    help="with --plan: seed an assumed duration drift, e.g. "
                         "cc_edges@pod-spot=3.0 (repeatable; implies "
                         "adaptive pricing)")
    ap.add_argument("--resume", default=None, metavar="RUN_ID",
                    help="preview crash recovery for a journaled run: "
                         "replay its journal and print landed/billed/"
                         "frontier state (requires --journal-dir)")
    ap.add_argument("--journal-dir", default=None,
                    help="run-journal directory for --resume")
    args = ap.parse_args()

    if args.resume:
        if not args.journal_dir:
            raise SystemExit("--resume requires --journal-dir")
        resume_preview(args.journal_dir, args.resume)
        return

    if args.plan:
        plan_preview(args.objective, args.time_value, args.budget_usd,
                     args.deadline_h, plan_rows=args.plan_rows,
                     select=args.select, adaptive=args.adaptive,
                     drift=args.drift)
        return

    if args.list:
        for a in list_configs():
            cfg = get_config(a)
            print(a, [s.name for s in applicable_shapes(cfg)])
        return

    archs = [args.arch] if args.arch else list_configs()
    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        # iterate ALL shapes: run_cell records inapplicable cells as explicit
        # 'skipped' rows (the 40-cell accounting in §Roofline)
        shapes = [args.shape] if args.shape else list(SHAPES)
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                out_path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_name}.json")
                cell = run_cell(arch, shape_name, mp)
                with open(out_path, "w") as f:
                    json.dump(cell, f, indent=1)
                status = cell["status"]
                extra = ""
                if status == "ok":
                    r = cell["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" step={r['step_time_s']:.4f}s"
                             f" compile={cell['compile_s']:.1f}s")
                elif status == "error":
                    failures += 1
                    extra = " " + cell["error"][:200]
                print(f"DRYRUN {arch} x {shape_name} x {mesh_name}: "
                      f"{status}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
