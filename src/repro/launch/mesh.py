"""Mesh construction (functions only — importing this module never touches
jax device state; jax locks the device count on first backend init)."""
from __future__ import annotations

import jax

from repro.distributed.sharding import MeshInfo


def make_production_mesh(*, multi_pod: bool = False):
    """Assigned production meshes: 16x16 single pod (256 v5e chips) or
    2x16x16 multi-pod (512 chips).  The 'pod' axis is pure DP; its gradient
    all-reduce crosses the slow inter-pod links (see grad compression)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def make_mesh_info(*, multi_pod: bool = False) -> MeshInfo:
    return MeshInfo(make_production_mesh(multi_pod=multi_pod))


def small_mesh_info(shape=(2, 2), axes=("data", "model")) -> MeshInfo:
    """Tiny mesh for CI-scale multi-device tests (run under
    --xla_force_host_platform_device_count)."""
    return MeshInfo(jax.make_mesh(shape, axes))
