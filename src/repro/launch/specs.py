"""input_specs(): ShapeDtypeStruct stand-ins for every model input, per
(architecture x assigned shape), with NamedShardings attached — weak-type
correct, shardable, zero device allocation (the dry-run contract).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import MeshInfo
from repro.models import LanguageModel
from repro.models.model import _is_spec_leaf


def _sds(info: MeshInfo | None, shape, dtype, axes) -> jax.ShapeDtypeStruct:
    if info is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=info.sharding(shape, axes))


def batch_specs(cfg: ModelConfig, shape: ShapeSpec,
                info: MeshInfo | None) -> dict[str, Any]:
    """Training/prefill batch inputs."""
    b, s = shape.global_batch, shape.seq_len
    specs: dict[str, Any] = {
        "tokens": _sds(info, (b, s), jnp.int32, ("batch", "seq_act")),
    }
    if shape.kind == "train":
        specs["targets"] = _sds(info, (b, s), jnp.int32, ("batch", "seq_act"))
        specs["weights"] = _sds(info, (b, s), jnp.float32, ("batch", "seq_act"))
    if cfg.enc_dec:  # audio frontend STUB: precomputed frame embeddings
        specs["frames"] = _sds(info, (b, s, cfg.d_model), jnp.float32,
                               ("batch", "seq_act", None))
    if cfg.pos_type == "mrope":  # vision frontend STUB: M-RoPE coordinates
        specs["positions"] = _sds(info, (3, b, s), jnp.int32,
                                  (None, "batch", "seq_act"))
    return specs


def cache_input_specs(model: LanguageModel, shape: ShapeSpec,
                      info: MeshInfo | None, dtype=jnp.bfloat16) -> Any:
    cfg = model.cfg
    specs = model.cache_specs(shape.global_batch, shape.seq_len,
                              enc_len=shape.seq_len, dtype=dtype)

    def attach(leaf):
        sds, axes = leaf
        return _sds(info, sds.shape, sds.dtype, axes)

    return jax.tree.map(attach, specs, is_leaf=_is_spec_leaf)


def decode_token_specs(cfg: ModelConfig, shape: ShapeSpec,
                       info: MeshInfo | None) -> tuple[Any, Any]:
    b = shape.global_batch
    tokens = _sds(info, (b, 1), jnp.int32, ("batch", None))
    pos = _sds(info, (b,), jnp.int32, ("batch",))
    return tokens, pos


def param_specs(model: LanguageModel, info: MeshInfo | None,
                dtype: str | None = None) -> Any:
    """Abstract parameters with shardings (no allocation)."""
    shapes = model.abstract_params()
    axes = model.param_axes

    def attach(sds, ax):
        dt = sds.dtype if dtype is None else jnp.dtype(dtype)
        # norms/scalars stay f32 even when serving weights are bf16
        if dtype is not None and sds.ndim <= 1:
            dt = sds.dtype
        return _sds(info, sds.shape, dt, ax)

    return jax.tree.map(attach, shapes, axes,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(e, (str, type(None))) for e in x))
