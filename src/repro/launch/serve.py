"""Batched serving driver: continuous batching over a slot pool.

Requests (prompt token lists) are admitted into fixed decode slots; prefill
fills a slot's KV cache, then all active slots decode in lockstep (one jitted
decode_step per tick, per-slot positions — the KV caches carry explicit slot
positions, so ragged occupancy is exact).  On a pod the same step functions
run sharded; the dry-run's decode cells prove those lower.
"""
from __future__ import annotations

import argparse
import dataclasses
import importlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LanguageModel


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, model: LanguageModel, params, n_slots: int = 4,
                 max_len: int = 256):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = model.init_cache(n_slots, max_len, enc_len=8)
        self._slot_specs = model.cache_specs(1, max_len, enc_len=8)
        self.pos = np.zeros((n_slots,), np.int32)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.last_token = np.zeros((n_slots,), np.int32)
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self._write_slot = jax.jit(self._write_slot_impl,
                                   static_argnames=("slot",))

    def _write_slot_impl(self, batched, single, *, slot: int):
        """Scatter a freshly-prefilled B=1 cache into slot `slot` of the
        batched cache.  The batch dim of every leaf is located via the cache
        spec's logical axes (scanned segments carry a leading layers dim)."""
        from repro.models.model import _is_spec_leaf

        def write(b, s_, spec):
            bdim = list(spec[1]).index("batch")
            idx = [slice(None)] * b.ndim
            idx[bdim] = slot
            src = jnp.take(s_, 0, axis=bdim)
            return b.at[tuple(idx)].set(src.astype(b.dtype))

        return jax.tree.map(
            lambda b, s_, spec: write(b, s_, spec), batched, single,
            self._slot_specs,
            is_leaf=lambda x: _is_spec_leaf(x) or not isinstance(x, dict))

    def admit(self, req: Request) -> bool:
        for s in range(self.n_slots):
            if self.slot_req[s] is None:
                self.slot_req[s] = req
                # real batched prefill into a B=1 cache, then slot-scatter —
                # the same `prefill` the dry-run's prefill cells lower
                cache1 = self.model.init_cache(1, self.max_len, enc_len=8)
                tokens = jnp.asarray([req.prompt], jnp.int32)
                logits, cache1 = self._prefill(self.params,
                                               {"tokens": tokens}, cache1)
                self.cache = self._write_slot(self.cache, cache1, slot=s)
                self.pos[s] = len(req.prompt)
                self.last_token[s] = int(np.argmax(np.asarray(logits)[0]))
                return True
        return False

    def step(self) -> None:
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return
        t = self.last_token.reshape(-1, 1).astype(np.int32)
        logits, self.cache = self._decode(self.params, jnp.asarray(t),
                                          self.cache, jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for s in active:
            req = self.slot_req[s]
            req.out.append(int(t[s, 0]))
            self.pos[s] += 1
            self.last_token[s] = nxt[s]
            if (len(req.out) >= req.max_new
                    or self.pos[s] >= self.max_len - 1):
                req.done = True
                self.slot_req[s] = None

    def run(self, requests: list[Request]) -> dict:
        queue = list(requests)
        t0 = time.time()
        ticks = 0
        while queue or any(self.slot_req):
            while queue and self.admit(queue[0]):
                queue.pop(0)
            self.step()
            ticks += 1
        wall = time.time() - t0
        toks = sum(len(r.out) for r in requests)
        return {"requests": len(requests), "tokens": toks, "ticks": ticks,
                "wall_s": wall, "tok_per_s": toks / max(wall, 1e-9)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    mod = importlib.import_module(
        "repro.configs." + args.arch.replace("-", "_").replace(".", "_"))
    cfg = mod.smoke()
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batcher = ContinuousBatcher(model, params, n_slots=args.slots)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size, 8).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    stats = batcher.run(reqs)
    print(f"[serve {args.arch}] {stats}")


if __name__ == "__main__":
    main()
