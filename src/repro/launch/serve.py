"""Serving drivers: paged high-throughput engine + dense reference batcher.

Two implementations share the ``Request`` interface:

``PagedServingEngine`` (the production path)
    Block-table-backed paged KV cache (``launch/paged_kv.py``), chunked
    prefill interleaved with decode ticks (a long prompt never stalls the
    active streams), exact power-of-two prompt bucketing (bounded jit trace
    count, zero padding), device-resident decode state with on-device argmax,
    and a bounded host-sync cadence — outputs drain every ``drain_every``
    ticks instead of every tick.  Completion is deterministic (count-based),
    so the host schedules without reading the device between drains.

``ContinuousBatcher`` (the dense reference)
    The original lockstep batcher: dense ``(n_slots, max_len)`` caches, full
    unchunked prefill at admission (jit retraces per prompt length), one
    host sync per tick.  Kept as the benchmark baseline and the simplest
    correctness oracle.

Both report ``host_syncs`` and device↔host byte counters in their run stats
so regressions in host chatter show up in BENCH_serving.json, not just wall
time.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import importlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LanguageModel
from repro.launch.paged_kv import PagedKVCache, decompose


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    arrival: int = 0  # earliest admit tick (0 = already queued)
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    rejected: bool = False
    admit_tick: int = -1
    finish_tick: int = -1


# ---------------------------------------------------------------------------
# Paged serving engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Prefilling:
    req: Request
    start: int  # next prompt position to compute
    frames: jax.Array | None = None


class PagedServingEngine:
    """Hundreds of concurrent streams over a shared paged KV pool.

    Per engine iteration: one device-resident *block* of ``drain_every``
    batched decode ticks (a ``lax.scan`` in a single dispatch; inactive
    slots are masked by ``pos == -1`` and mutate nothing), then up to
    ``prefill_chunks_per_tick`` prefill chunks for admitted-but-not-yet-
    decoding requests.  Output tokens accumulate in a device ring and drain
    to the host once per block; freed slots are recycled at drain
    boundaries.
    """

    def __init__(self, model: LanguageModel, params, n_slots: int = 64,
                 max_len: int = 256, page_size: int = 16,
                 pool_fraction: float = 1.0, chunk_max: int = 64,
                 drain_every: int = 8, prefill_chunks_per_tick: int = 1,
                 prefill_group: int = 8, enc_len: int = 0,
                 dtype=jnp.bfloat16):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.chunk_max = chunk_max
        self.drain_every = drain_every
        self.prefill_chunks_per_tick = prefill_chunks_per_tick
        self.prefill_group = prefill_group
        max_pages = -(-max_len // page_size)
        n_pages = max(1, int(n_slots * max_pages * pool_fraction))
        self.kv = PagedKVCache(model, n_slots, n_pages, page_size, max_pages,
                               enc_len=enc_len, dtype=dtype)

        B = n_slots
        self.last_token = jnp.zeros((B,), jnp.int32)
        self.pos = jnp.full((B,), -1, jnp.int32)
        self.remaining = jnp.zeros((B,), jnp.int32)
        self.out_buf = jnp.zeros((B, drain_every), jnp.int32)
        self.out_cnt = jnp.zeros((B,), jnp.int32)

        # host mirrors (decode emission is deterministic: one token per
        # active slot per tick, so no device reads are needed to schedule)
        self.slot_req: list[Request | None] = [None] * B
        self._active: set[int] = set()        # emitting slots
        self._finished: set[int] = set()      # done, tokens pending drain
        self._pf: collections.OrderedDict[int, _Prefilling] = \
            collections.OrderedDict()
        self._remaining_h = np.zeros((B,), np.int64)

        self.stats_counters = {
            "host_syncs": 0, "bytes_to_host": 0, "bytes_to_device": 0,
            "drains": 0, "prefill_chunks": 0, "decode_ticks": 0,
            "stall_ticks": 0,
        }
        self._window_walls: list[float] = []  # (wall_s, ticks) per drain gap

        def tick_block(cache, table, last, pos, remaining, out_buf, out_cnt):
            """``drain_every`` decode ticks in one dispatch: the decode loop
            is device-resident between drains, so per-call overhead (pytree
            flattening, dispatch) is paid once per K tokens per slot."""
            def body(carry, _):
                cache, last, pos, remaining, out_buf, out_cnt = carry
                emit = remaining > 0
                pos_eff = jnp.where(emit, pos, -1)
                logits, cache = model.decode_step(params, last[:, None],
                                                  cache, pos_eff, table=table)
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                b = jnp.arange(B)
                # emit the *input* token (seed semantics: the first emitted
                # token is the post-prefill argmax); inactive columns land
                # OOB -> dropped
                col = jnp.where(emit, out_cnt, drain_every)
                out_buf = out_buf.at[b, col].set(last)
                inc = emit.astype(jnp.int32)
                return (cache, jnp.where(emit, nxt, last), pos + inc,
                        remaining - inc, out_buf, out_cnt + inc), None

            carry, _ = jax.lax.scan(
                body, (cache, last, pos, remaining, out_buf, out_cnt),
                None, length=drain_every)
            return carry

        def chunk(cache, table, slots, tokens, start, frames):
            """One batched prefill round: G slots advance one chunk each.
            Padded group entries (slot == n_slots, start == -1) gather init
            values, compute garbage, and scatter out of bounds -> dropped."""
            rows = jnp.take(table, slots, axis=0, mode="fill",
                            fill_value=self.kv.n_pages)
            view = self.kv._gather_impl(cache, rows, slots)
            batch = {"tokens": tokens}
            if frames is not None:
                batch["frames"] = frames
            logits, view = model.prefill_chunk(params, batch, view, start)
            cache = self.kv._scatter_impl(cache, view, rows, slots)
            return cache, logits

        def finalize(last, pos, remaining, logits, slot, plen, max_new):
            tok = jnp.argmax(logits[0]).astype(jnp.int32)
            return (last.at[slot].set(tok), pos.at[slot].set(plen),
                    remaining.at[slot].set(max_new))

        # params are closure constants (no per-call flatten of the weight
        # tree) and the threaded state is donated so XLA updates the multi-MB
        # cache pools in place instead of copying them every block/chunk
        self._tick_block = jax.jit(tick_block,
                                   donate_argnums=(0, 2, 3, 4, 5, 6))
        self._chunk = jax.jit(chunk, donate_argnums=(0,))
        self._finalize = jax.jit(finalize, donate_argnums=(0, 1, 2))

    # ----------------------------------------------------------- scheduling
    def _admit(self, queue: collections.deque[Request], now: int) -> None:
        """Scan the whole queue (no head-of-line blocking): any request whose
        page reservation fits an open slot is admitted; over-sized requests
        are rejected outright instead of wedging the queue."""
        free_slots = [s for s in range(self.n_slots)
                      if self.slot_req[s] is None]
        if not free_slots:
            return
        keep: list[Request] = []
        while queue:
            req = queue.popleft()
            need = len(req.prompt) + req.max_new + 1
            if self.kv.pages_needed(need) > self.kv.max_pages:
                req.rejected = True
                req.done = True
                continue
            if free_slots and self.kv.can_alloc(need):
                slot = free_slots.pop(0)
                self.kv.alloc(slot, need)
                self.slot_req[slot] = req
                req.admit_tick = now
                self._pf[slot] = _Prefilling(req=req, start=0)
            else:
                keep.append(req)
        queue.extend(keep)

    def _prefill_step(self) -> None:
        """One batched prefill round: the oldest prefilling request picks the
        chunk size, every other pending request at the same size joins the
        group (up to ``prefill_group``), one jit call advances them all."""
        if not self._pf:
            return
        _, oldest = next(iter(self._pf.items()))
        c = decompose(len(oldest.req.prompt) - oldest.start, self.chunk_max)[0]
        members = [
            (slot, st) for slot, st in self._pf.items()
            if decompose(len(st.req.prompt) - st.start, self.chunk_max)[0] == c
        ][:self.prefill_group]

        G = self.prefill_group
        tokens = np.zeros((G, c), np.int32)
        starts = np.full((G,), -1, np.int32)
        slots = np.full((G,), self.n_slots, np.int32)  # pad -> OOB drop
        for i, (slot, st) in enumerate(members):
            tokens[i] = st.req.prompt[st.start:st.start + c]
            starts[i] = st.start
            slots[i] = slot
        tokens = jnp.asarray(tokens)
        self.stats_counters["bytes_to_device"] += int(tokens.nbytes)
        self.kv.cache, logits = self._chunk(
            self.kv.cache, self.kv.table, jnp.asarray(slots), tokens,
            jnp.asarray(starts), members[0][1].frames)
        self.stats_counters["prefill_chunks"] += len(members)
        for i, (slot, st) in enumerate(members):
            st.start += c
            if st.start >= len(st.req.prompt):
                del self._pf[slot]
                self.last_token, self.pos, self.remaining = self._finalize(
                    self.last_token, self.pos, self.remaining, logits[i][None],
                    jnp.asarray(slot, jnp.int32),
                    jnp.asarray(len(st.req.prompt), jnp.int32),
                    jnp.asarray(st.req.max_new, jnp.int32))
                self._active.add(slot)
                self._remaining_h[slot] = st.req.max_new

    def _drain(self, now: int) -> None:
        out_buf, out_cnt = jax.device_get((self.out_buf, self.out_cnt))
        self.stats_counters["host_syncs"] += 1
        self.stats_counters["bytes_to_host"] += (
            int(self.out_buf.nbytes) + int(self.out_cnt.nbytes))
        self.stats_counters["drains"] += 1
        for slot in list(self._active | self._finished):
            req = self.slot_req[slot]
            req.out.extend(int(t) for t in out_buf[slot, :out_cnt[slot]])
            if slot in self._finished or len(req.out) >= req.max_new:
                req.done = True
                if req.finish_tick < 0:
                    req.finish_tick = now
                self.slot_req[slot] = None
                self.kv.free(slot)
                self._active.discard(slot)
                self._finished.discard(slot)
        self.out_cnt = jnp.zeros_like(self.out_cnt)

    # ------------------------------------------------------------------ run
    def run(self, requests: list[Request]) -> dict:
        # re-entrant: a warm engine can serve successive traces (benchmarks
        # reuse one instance so jit compiles are paid once, not per run)
        self.stats_counters = dict.fromkeys(self.stats_counters, 0)
        self._window_walls = []
        pending = collections.deque(sorted(requests, key=lambda r: r.arrival))
        queue: collections.deque[Request] = collections.deque()
        t0 = time.time()
        ticks = 0
        ran_block = False
        window_t0 = t0
        K = self.drain_every
        while (pending or queue or self._active or self._finished
               or self._pf):
            while pending and pending[0].arrival <= ticks:
                queue.append(pending.popleft())
            self._admit(queue, ticks)

            if self._active:
                # one device-resident block: K decode ticks, zero host reads
                window_t0 = time.time()
                (self.kv.cache, self.last_token, self.pos, self.remaining,
                 self.out_buf, self.out_cnt) = self._tick_block(
                    self.kv.cache, self.kv.table, self.last_token, self.pos,
                    self.remaining, self.out_buf, self.out_cnt)
                self.stats_counters["decode_ticks"] += K
                ran_block = True
                for slot in list(self._active):
                    left = self._remaining_h[slot]
                    if left <= K:
                        self._active.discard(slot)
                        self._finished.add(slot)
                        self.slot_req[slot].finish_tick = ticks + int(left)
                        self._remaining_h[slot] = 0
                    else:
                        self._remaining_h[slot] = left - K
                ticks += K
            elif self._pf:
                self.stats_counters["stall_ticks"] += 1
            elif pending and not queue:
                ticks = max(ticks, pending[0].arrival)  # idle until arrival

            # prefill backpressure: flood chunks while decode is
            # under-saturated (filling slots beats tail latency), trickle one
            # round per block once half the slots are streaming
            rounds = (self.prefill_chunks_per_tick
                      if len(self._active) < self.n_slots // 2 else 1)
            for _ in range(rounds):
                self._prefill_step()

            idle = not self._active and not self._pf
            if ran_block or (idle and self._finished):
                # window = block dispatch -> everything flushed, so the
                # tick_ms percentiles include interleaved prefill work (the
                # interference being measured) but not host-side admission
                self.last_token.block_until_ready()
                now = time.time()
                if ran_block:
                    self._window_walls.append((now - window_t0, K))
                self._drain(ticks)
                ran_block = False
            elif (queue and not self._active and not self._pf
                  and not self._finished):
                # pages exhausted by queued work that can never fit together;
                # admit rejected everything it could — avoid spinning
                req = queue.popleft()
                req.rejected = True
                req.done = True

        wall = time.time() - t0
        served = [r for r in requests if not r.rejected]
        toks = sum(len(r.out) for r in served)
        lat = sorted((r.finish_tick - r.arrival) for r in served
                     if r.finish_tick >= 0)
        per_tick = sorted(w / n for w, n in self._window_walls if n)
        stats = {
            "engine": "paged",
            "requests": len(requests),
            "rejected": sum(r.rejected for r in requests),
            "tokens": toks,
            "ticks": ticks,
            "wall_s": wall,
            "tok_per_s": toks / max(wall, 1e-9),
            "p50_latency_ticks": _pct(lat, 0.50),
            "p99_latency_ticks": _pct(lat, 0.99),
            "tick_ms_p50": _pct(per_tick, 0.50) * 1e3,
            "tick_ms_p99": _pct(per_tick, 0.99) * 1e3,
            "prefill_stall_fraction": (
                self.stats_counters["stall_ticks"]
                / max(ticks + self.stats_counters["stall_ticks"], 1)),
            "page_utilization": self.kv.stats().utilization,
        }
        stats.update(self.stats_counters)
        return stats


def _pct(sorted_vals: list, q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1) + 0.5))
    return float(sorted_vals[i])


# ---------------------------------------------------------------------------
# Dense reference batcher (benchmark baseline + correctness oracle)
# ---------------------------------------------------------------------------


class ContinuousBatcher:
    def __init__(self, model: LanguageModel, params, n_slots: int = 4,
                 max_len: int = 256, enc_len: int = 8):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.enc_len = enc_len
        self.cache = model.init_cache(n_slots, max_len, enc_len=enc_len)
        self._slot_specs = model.cache_specs(1, max_len, enc_len=enc_len)
        self.pos = np.zeros((n_slots,), np.int32)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.last_token = np.zeros((n_slots,), np.int32)
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self._write_slot = jax.jit(self._write_slot_impl,
                                   static_argnames=("slot",))
        self.stats_counters = {"host_syncs": 0, "bytes_to_host": 0,
                               "bytes_to_device": 0}

    def _write_slot_impl(self, batched, single, *, slot: int):
        """Scatter a freshly-prefilled B=1 cache into slot `slot` of the
        batched cache.  The batch dim of every leaf is located via the cache
        spec's logical axes (scanned segments carry a leading layers dim)."""
        from repro.models.model import _is_spec_leaf

        def write(b, s_, spec):
            bdim = list(spec[1]).index("batch")
            idx = [slice(None)] * b.ndim
            idx[bdim] = slot
            src = jnp.take(s_, 0, axis=bdim)
            return b.at[tuple(idx)].set(src.astype(b.dtype))

        return jax.tree.map(
            lambda b, s_, spec: write(b, s_, spec), batched, single,
            self._slot_specs,
            is_leaf=lambda x: _is_spec_leaf(x) or not isinstance(x, dict))

    def admit(self, req: Request) -> bool:
        if len(req.prompt) + req.max_new + 1 > self.max_len:
            req.rejected = True
            req.done = True
            return True  # consumed (dropped), don't block the queue
        for s in range(self.n_slots):
            if self.slot_req[s] is None:
                self.slot_req[s] = req
                # real batched prefill into a B=1 cache, then slot-scatter —
                # the same `prefill` the dry-run's prefill cells lower
                cache1 = self.model.init_cache(1, self.max_len,
                                               enc_len=self.enc_len)
                tokens = jnp.asarray([req.prompt], jnp.int32)
                self.stats_counters["bytes_to_device"] += int(tokens.nbytes)
                logits, cache1 = self._prefill(self.params,
                                               {"tokens": tokens}, cache1)
                self.cache = self._write_slot(self.cache, cache1, slot=s)
                self.pos[s] = len(req.prompt)
                host_logits = np.asarray(logits)
                self.stats_counters["host_syncs"] += 1
                self.stats_counters["bytes_to_host"] += int(host_logits.nbytes)
                self.last_token[s] = int(np.argmax(host_logits[0]))
                return True
        return False

    def step(self) -> None:
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return
        t = self.last_token.reshape(-1, 1).astype(np.int32)
        logits, self.cache = self._decode(self.params, jnp.asarray(t),
                                          self.cache, jnp.asarray(self.pos))
        self.stats_counters["bytes_to_device"] += t.nbytes + self.pos.nbytes
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        self.stats_counters["host_syncs"] += 1
        self.stats_counters["bytes_to_host"] += int(nxt.nbytes)
        for s in active:
            req = self.slot_req[s]
            req.out.append(int(t[s, 0]))
            self.pos[s] += 1
            self.last_token[s] = nxt[s]
            if (len(req.out) >= req.max_new
                    or self.pos[s] >= self.max_len - 1):
                req.done = True
                self.slot_req[s] = None

    def run(self, requests: list[Request]) -> dict:
        self.stats_counters = dict.fromkeys(self.stats_counters, 0)
        queue = collections.deque(requests)
        t0 = time.time()
        ticks = 0
        while queue or any(self.slot_req):
            # scan past non-admissible heads: a full pool stops the scan
            # (admit can only fail on capacity), but oversized requests are
            # consumed as rejected instead of wedging the queue forever
            n = len(queue)
            for _ in range(n):
                req = queue.popleft()
                if not self.admit(req):
                    queue.appendleft(req)
                    break
            self.step()
            ticks += 1
        wall = time.time() - t0
        served = [r for r in requests if not r.rejected]
        toks = sum(len(r.out) for r in served)
        stats = {"engine": "dense", "requests": len(requests),
                 "rejected": sum(r.rejected for r in requests),
                 "tokens": toks, "ticks": ticks, "wall_s": wall,
                 "tok_per_s": toks / max(wall, 1e-9)}
        stats.update(self.stats_counters)
        return stats


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--engine", choices=("paged", "dense"), default="paged")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--drain-every", type=int, default=8)
    ap.add_argument("--enc-len", type=int, default=8)
    args = ap.parse_args()

    mod = importlib.import_module(
        "repro.configs." + args.arch.replace("-", "_").replace(".", "_"))
    cfg = mod.smoke()
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size, 8).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    if args.engine == "paged":
        eng = PagedServingEngine(model, params, n_slots=args.slots,
                                 max_len=args.max_len,
                                 page_size=args.page_size,
                                 drain_every=args.drain_every,
                                 enc_len=args.enc_len)
        stats = eng.run(reqs)
    else:
        batcher = ContinuousBatcher(model, params, n_slots=args.slots,
                                    max_len=args.max_len,
                                    enc_len=args.enc_len)
        stats = batcher.run(reqs)
    print(f"[serve {args.arch}] {stats}")


if __name__ == "__main__":
    main()
