"""Compiled-artifact analysis: cost_analysis, memory_analysis, and collective
byte accounting parsed from the post-SPMD HLO (shapes there are per-device
shard shapes, which is exactly the per-chip roofline denominator).
"""
from __future__ import annotations

import re
from typing import Any

import numpy as np

from repro.core.platforms import HBM_BW, ICI_BW, PEAK_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shape: f32[16,128]{1,0}; tuples: (f32[1,2]{...}, bf16[3]{...})
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+(\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(", )
_COMP_HDR_RE = re.compile(r"^(?:ENTRY )?%([\w.\-]+) \(.*\) -> .+ \{")
_WHILE_RE = re.compile(r"condition=%([\w.\-]+), body=%([\w.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_bytes_f32(type_str: str) -> int:
    """Bytes contributed by f32 sub-shapes only (see dtype correction)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt != "f32":
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * 4
    return total


def _split_computations(hlo_text: str) -> dict[str, str]:
    comps: dict[str, list[str]] = {}
    name = None
    for ln in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(ln)
        if m and not ln.startswith(" "):
            name = m.group(1)
            comps[name] = []
        elif name is not None:
            if ln.startswith("}"):
                name = None
            else:
                comps[name].append(ln)
    return {k: "\n".join(v) for k, v in comps.items()}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device bytes moved by each collective kind, with while-loop bodies
    scaled by their trip counts (XLA cost analysis counts loop bodies once —
    scan-over-layers would otherwise be under-counted by ~n_layers x).

    Byte proxy per op = result-shape bytes ('-done' halves of async pairs are
    skipped).  Trip count = the loop bound constant in the condition region.
    """
    comps = _split_computations(hlo_text)

    kinds_all = _COLLECTIVES + ("f32_portion",)
    own: dict[str, dict[str, float]] = {}
    own_counts: dict[str, dict[str, int]] = {}
    refs: dict[str, list[tuple[str, float]]] = {}
    for name, text in comps.items():
        o = {k: 0.0 for k in kinds_all}
        c = {k: 0 for k in _COLLECTIVES}
        for m in _OP_RE.finditer(text):
            type_str, kind, phase = m.group(1), m.group(2), m.group(3)
            if phase == "-done":
                continue
            o[kind] += _shape_bytes(type_str)
            o["f32_portion"] += _shape_bytes_f32(type_str)
            c[kind] += 1
        own[name] = o
        own_counts[name] = c
        r: list[tuple[str, float]] = []
        for ln in text.splitlines():
            wm = _WHILE_RE.search(ln)
            if wm:
                cond, body = wm.groups()
                consts = [int(x) for x in _CONST_RE.findall(comps.get(cond, ""))]
                trip = float(max([x for x in consts if x > 0] or [1]))
                r.append((body, trip))
                continue
            bm = _BRANCH_RE.search(ln)
            if bm:
                for b in bm.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b:
                        r.append((b, 1.0))
            for cm in _CALL_RE.finditer(ln):
                r.append((cm.group(1), 1.0))
        refs[name] = r

    memo: dict[str, dict[str, float]] = {}

    def total(name: str, stack: frozenset) -> dict[str, float]:
        if name in memo:
            return memo[name]
        if name not in own or name in stack:
            return {k: 0.0 for k in kinds_all}
        acc = dict(own[name])
        for child, mult in refs[name]:
            sub = total(child, stack | {name})
            for k in kinds_all:
                acc[k] += mult * sub[k]
        memo[name] = acc
        return acc

    entry = None
    for ln in hlo_text.splitlines():
        if ln.startswith("ENTRY "):
            m = _COMP_HDR_RE.match(ln)
            if m:
                entry = m.group(1)
    if entry is None:
        entry = max(comps, key=lambda k: len(comps[k])) if comps else ""

    out: dict[str, Any] = dict(total(entry, frozenset())) if entry else \
        {k: 0.0 for k in kinds_all}
    out["total_raw"] = sum(out[k] for k in _COLLECTIVES)
    # dtype correction: the CPU backend normalizes bf16 -> f32 *before* SPMD
    # partitioning (verified on a minimal sharded bf16 matmul), so every f32
    # collective here would move bf16 on TPU.  Genuinely-f32 tensors in this
    # codebase (loss stats, router logits) are tiny, so halving the f32
    # portion is the honest TPU estimate; both values are reported.
    out["total"] = out["total_raw"] - 0.5 * out.pop("f32_portion")
    static = {k: sum(own_counts[n][k] for n in own_counts)
              for k in _COLLECTIVES}
    out["op_counts"] = static
    return out


def top_collectives(hlo_text: str, k: int = 12) -> list[dict[str, Any]]:
    """The §Perf diagnostic: largest collectives by trip-scaled bytes,
    with their shapes and loop multipliers."""
    comps = _split_computations(hlo_text)

    # compute the execution multiplier of every computation (entry = 1)
    mult: dict[str, float] = {}
    entry = None
    for ln in hlo_text.splitlines():
        if ln.startswith("ENTRY "):
            m = _COMP_HDR_RE.match(ln)
            if m:
                entry = m.group(1)
    if entry is None and comps:
        entry = max(comps, key=lambda kk: len(comps[kk]))

    def walk(name: str, m: float, stack: frozenset) -> None:
        if name not in comps or name in stack:
            return
        mult[name] = mult.get(name, 0.0) + m
        for ln in comps[name].splitlines():
            wm = _WHILE_RE.search(ln)
            if wm:
                cond, body = wm.groups()
                consts = [int(x) for x in _CONST_RE.findall(comps.get(cond, ""))]
                trip = float(max([x for x in consts if x > 0] or [1]))
                walk(body, m * trip, stack | {name})
                continue
            bm = _BRANCH_RE.search(ln)
            if bm:
                for b in bm.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b:
                        walk(b, m, stack | {name})
            for cm in _CALL_RE.finditer(ln):
                walk(cm.group(1), m, stack | {name})

    if entry:
        walk(entry, 1.0, frozenset())

    rows = []
    for name, text in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for om in _OP_RE.finditer(text):
            type_str, kind, phase = om.group(1), om.group(2), om.group(3)
            if phase == "-done":
                continue
            b = _shape_bytes(type_str)
            rows.append({
                "kind": kind, "shape": type_str[:90], "bytes": b,
                "trips": m, "total_bytes": b * m, "computation": name[:60],
            })
    rows.sort(key=lambda r: -r["total_bytes"])
    return rows[:k]


def safe_cost_analysis(compiled: Any) -> dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float, np.floating))}
    except Exception as e:  # pragma: no cover
        return {"error": -1.0, "_msg": str(e)}  # type: ignore[dict-item]


def safe_memory_analysis(compiled: Any) -> dict[str, float]:
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        out = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "host_argument_size_in_bytes",
                  "peak_memory_in_bytes"):
            if hasattr(ma, k):
                out[k] = float(getattr(ma, k))
        return out
    except Exception:  # pragma: no cover
        return {}


def argument_bytes(lowered_args: Any) -> float:
    """Fallback per-device residency: sum of sharded argument sizes."""
    import jax

    total = 0.0
    for leaf in jax.tree.leaves(lowered_args):
        if not hasattr(leaf, "shape"):
            continue
        n = float(np.prod(leaf.shape)) if leaf.shape else 1.0
        n *= np.dtype(leaf.dtype).itemsize
        sh = getattr(leaf, "sharding", None)
        if sh is not None and hasattr(sh, "num_devices") and sh.num_devices:
            try:
                shard_shape = sh.shard_shape(leaf.shape)
                n = float(np.prod(shard_shape)) * np.dtype(leaf.dtype).itemsize
            except Exception:
                n /= sh.num_devices
        total += n
    return total


def roofline(flops_per_device: float, hbm_bytes_per_device: float,
             coll_bytes_per_device: float, model_flops_total: float,
             n_chips: int) -> dict[str, float]:
    t_comp = flops_per_device / PEAK_FLOPS
    t_mem = hbm_bytes_per_device / HBM_BW
    t_coll = coll_bytes_per_device / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)  # type: ignore[arg-type]
    step_time = max(terms.values())
    useful = model_flops_total / max(1.0, flops_per_device * n_chips)
    mfu = (model_flops_total / n_chips / PEAK_FLOPS) / max(step_time, 1e-12)
    return {
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,  # type: ignore[dict-item]
        "step_time_s": step_time,
        "useful_flops_ratio": useful,
        "model_flops_util": mfu,
    }
