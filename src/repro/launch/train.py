"""End-to-end training driver: data pipeline -> sharded train step ->
checkpoint/restart fault tolerance.

On this CPU container it runs reduced (--smoke) configs for real; on a pod it
is the same code with --mesh pod/multipod (the dry-run proves those lower).
Auto-resume: the latest committed checkpoint is picked up after any crash or
preemption (exercised by tests/test_train_resume.py with --preempt-at).
"""
from __future__ import annotations

import argparse
import importlib
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import TokenDataset
from repro.distributed.sharding import MeshInfo, use_mesh_info
from repro.models import LanguageModel
from repro.optim import AdamW, OptConfig


def smoke_config(arch: str):
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    return mod.smoke()


def make_train_step(model: LanguageModel, opt: AdamW):
    def train_step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(
            model.train_loss, has_aux=True)(params, batch)
        new_params, new_state, stats = opt.update(grads, opt_state, params)
        return new_params, new_state, {**metrics, **stats}

    return jax.jit(train_step, donate_argnums=(0, 1))


def train(arch: str = "gemma-2b", smoke: bool = True, steps: int = 50,
          global_batch: int = 8, seq_len: int = 128, peak_lr: float = 3e-3,
          ckpt_dir: str | None = None, save_every: int = 20,
          log_every: int = 10, resume: bool = True, seed: int = 0,
          preempt_at: int | None = None, mesh_info: MeshInfo | None = None,
          partition: str = "2024-01/all") -> dict[str, Any]:
    cfg = smoke_config(arch) if smoke else get_config(arch)
    model = LanguageModel(cfg)
    opt = AdamW(OptConfig(peak_lr=peak_lr, warmup_steps=max(2, steps // 10),
                          decay_steps=max(steps, 10)))
    data = TokenDataset(vocab_size=cfg.vocab_size, seq_len=seq_len,
                        global_batch=global_batch, partition=partition)

    with use_mesh_info(mesh_info):
        params = jax.jit(model.init)(jax.random.PRNGKey(seed))
        opt_state = opt.init(params)
        step = 0

        mgr = None
        if ckpt_dir:
            mgr = CheckpointManager(ckpt_dir, keep=3)
            if resume:
                got = mgr.restore_latest({"params": params,
                                          "opt_state": opt_state})
                if got is not None:
                    step, tree = got
                    params, opt_state = tree["params"], tree["opt_state"]
                    print(f"[train] resumed from step {step}")

        train_step = make_train_step(model, opt)
        history: list[dict[str, float]] = []
        t0 = time.time()
        while step < steps:
            batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
            params, opt_state, metrics = train_step(params, opt_state, batch)
            step += 1
            if step % log_every == 0 or step == steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["wall_s"] = time.time() - t0
                history.append(m)
                print(f"[train {arch}] step {step}: loss={m['loss']:.4f} "
                      f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e}")
            if mgr and (step % save_every == 0 or step == steps):
                mgr.save(step, {"params": params, "opt_state": opt_state},
                         metadata={"arch": arch, "step": step})
            if preempt_at is not None and step >= preempt_at:
                mgr and mgr.wait()
                print(f"[train] simulated preemption at step {step}")
                raise SystemExit(17)  # preemption exit code
        if mgr:
            mgr.wait()

    losses = [h["loss"] for h in history]
    return {"history": history, "final_loss": losses[-1] if losses else None,
            "first_loss": losses[0] if losses else None, "steps": step,
            "params": params}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--full", action="store_true",
                    help="use the full (pod-scale) config, not the smoke one")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--preempt-at", type=int, default=None)
    ap.add_argument("--partition", default="2024-01/all")
    args = ap.parse_args()
    out = train(arch=args.arch, smoke=not args.full, steps=args.steps,
                global_batch=args.batch, seq_len=args.seq, peak_lr=args.lr,
                ckpt_dir=args.ckpt_dir, save_every=args.save_every,
                log_every=args.log_every, resume=not args.no_resume,
                preempt_at=args.preempt_at, partition=args.partition)
    print(f"[train] done: first_loss={out['first_loss']:.4f} "
          f"final_loss={out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
