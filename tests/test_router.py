"""Cost-model-routed replica front-end: SLO traffic buys premium capacity
only when the deadline demands it, bulk traffic always takes the cheapest
replica, and per-replica circuit breakers reroute around hard failures."""
from repro.core.adaptive import OnlineCostModel
from repro.core.costmodel import CostModel
from repro.launch.router import (ReplicaRouter, ServeClass, default_replicas)

BULK = ServeClass("bulk", deadline_s=None)


def _router(**kw):
    return ReplicaRouter(default_replicas(), **kw)


def _wall(router, work, cls, name):
    r = router.replicas[name]
    est = router.price(work, cls, r)
    return router.model.schedule_duration(est, r.platform, cls.name)


def test_bulk_routes_to_cheapest_spot():
    router = _router()
    d = router.route(0, work_tokens=50_000, cls=BULK)
    assert d is not None
    assert router.replicas[d.replica].platform.kind == "spot"
    # spot is cheaper than premium even after its worse retry multiplier
    prem = router.price(50_000, BULK, router.replicas["premium-0"])
    prem_usd = router.model.expected_cost_with_retries(
        prem, router.replicas["premium-0"].platform, BULK.name)
    assert d.expected_usd < prem_usd


def test_tight_deadline_buys_premium():
    router = _router()
    work = 200_000
    spot_wall = _wall(router, work, BULK, "spot-0")
    prem_wall = _wall(router, work, BULK, "premium-0")
    assert prem_wall < spot_wall  # perf_factor 1.2x + better retry odds
    cls = ServeClass("interactive", deadline_s=(prem_wall + spot_wall) / 2)
    d = router.route(0, work, cls)
    assert d.replica == "premium-0"
    assert d.deadline_feasible
    assert router.counters["slo_to_premium"] == 1


def test_loose_deadline_stays_on_cheap_capacity():
    router = _router()
    work = 200_000
    cls = ServeClass("batchy", deadline_s=10 * _wall(router, work, BULK,
                                                     "spot-0"))
    d = router.route(0, work, cls)
    assert router.replicas[d.replica].platform.kind == "spot"
    assert d.deadline_feasible


def test_infeasible_deadline_degrades_to_fastest():
    router = _router()
    cls = ServeClass("impossible", deadline_s=1e-6)
    d = router.route(0, 200_000, cls)
    assert d is not None and not d.deadline_feasible
    assert d.replica == "premium-0"  # fastest wall, even though infeasible
    assert router.counters["slo_infeasible"] == 1


def test_breaker_reroutes_then_unroutable():
    # static CostModel: failures must not reprice spot above premium, so the
    # breaker (not the cost feedback) is what forces the reroute
    router = _router(model=CostModel(), breaker_failures=2,
                     breaker_cooldown_s=60.0)
    # hard-fail both spot replicas until their breakers open
    rid = 0
    for name in ("spot-0", "spot-1"):
        trips = 0
        while router.breakers[name].state != "open":
            d = router.route(rid, 1000, BULK, now=0.0)
            assert d is not None and d.replica == name
            router.complete(rid, "failure", realized_s=1.0, now=0.0)
            rid += 1
            trips += 1
            assert trips < 20  # must converge
    # bulk now lands on premium despite the price
    d = router.route(rid, 1000, BULK, now=1.0)
    assert d.replica == "premium-0"
    assert router.counters["breaker_denials"] > 0
    router.complete(rid, "failure", realized_s=1.0, now=1.0)
    rid += 1
    d = router.route(rid, 1000, BULK, now=1.0)
    router.complete(rid, "failure", realized_s=1.0, now=1.0)
    assert router.breakers["premium-0"].state == "open"
    # every breaker open inside the cooldown window -> unroutable
    assert router.route(99, 1000, BULK, now=2.0) is None
    assert router.counters["unroutable"] == 1
    # after the cooldown a single half-open probe is admitted again
    d = router.route(100, 1000, BULK, now=120.0)
    assert d is not None
    router.complete(100, "success", realized_s=1.0, now=120.0)
    assert router.breakers[d.replica].state == "closed"


def test_preemption_does_not_trip_breaker():
    router = _router(breaker_failures=2)
    for rid in range(6):
        d = router.route(rid, 1000, BULK, now=0.0)
        router.complete(rid, "preemption", realized_s=1.0, now=0.0)
    assert all(b.state == "closed" for b in router.breakers.values())


def test_observed_slowness_recalibrates_pricing():
    router = _router()
    assert isinstance(router.model, OnlineCostModel)
    cls = ServeClass("hot", deadline_s=None)
    base = router.price(10_000, cls, router.replicas["spot-0"]).compute_s
    for rid in range(12):  # replica consistently 3x slower than the catalog
        d = router.route(rid, 10_000, cls, now=0.0)
        router.complete(rid, "success",
                        realized_s=3.0 * d.estimate.compute_s, now=0.0)
    recal = router.price(10_000, cls, router.replicas["spot-0"]).compute_s
    assert recal > 1.5 * base  # EWMA pulled the duration ratio up


def test_backlog_tracks_inflight_and_drains():
    router = _router()
    d0 = router.route(0, 30_000, BULK)
    busy = router.replicas[d0.replica]
    assert busy.backlog_tokens > 0
    # a queued replica prices higher wall than an idle twin
    others = [r for r in router.replicas.values()
              if r.platform.kind == "spot" and r.name != busy.name]
    est_busy = router.price(1000, BULK, busy)
    est_idle = router.price(1000, BULK, others[0])
    assert est_busy.duration_s > est_idle.duration_s
    router.complete(0, "success", realized_s=est_busy.compute_s)
    assert busy.backlog_tokens == 0.0


def test_stats_shape():
    router = _router()
    router.route(0, 1000, BULK)
    router.complete(0, "success", realized_s=1.0)
    s = router.stats()
    assert s["routed"] == 1 and s["bulk_total"] == 1
    for name, rs in s["replicas"].items():
        assert set(rs) == {"platform", "backlog_tokens", "breaker", "trips"}
        assert rs["breaker"] in ("closed", "open", "half-open")
