"""Continuous-batching serving: ragged slot occupancy must reproduce the
sequential single-request decode exactly (greedy tokens)."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import ContinuousBatcher, PagedServingEngine, Request
from repro.models import LanguageModel


def _model(arch="gemma-2b"):
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    cfg = mod.smoke().scaled(compute_dtype="float32")
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _sequential_greedy(cfg, model, params, prompt, max_new):
    cache = model.init_cache(1, 64, enc_len=8, dtype=jnp.float32)
    logits = None
    for i, tok in enumerate(prompt):
        t = jnp.asarray([[tok]], jnp.int32)
        logits, cache = model.decode_step(params, t, cache,
                                          jnp.asarray([i], jnp.int32))
    out = []
    cur = int(jnp.argmax(logits[0]))
    pos = len(prompt)
    for _ in range(max_new):
        out.append(cur)
        logits, cache = model.decode_step(
            params, jnp.asarray([[cur]], jnp.int32), cache,
            jnp.asarray([pos], jnp.int32))
        cur = int(jnp.argmax(logits[0]))
        pos += 1
    return out


def test_continuous_batching_matches_sequential():
    cfg, model, params = _model()
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, 5).tolist() for _ in range(3)]
    max_new = 6

    refs = [_sequential_greedy(cfg, model, params, p, max_new)
            for p in prompts]

    batcher = ContinuousBatcher(model, params, n_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    stats = batcher.run(reqs)
    assert stats["tokens"] == 3 * max_new
    for r, ref in zip(reqs, refs):
        # first emitted token is argmax after prefill == ref[0]; subsequent
        # tokens follow the same greedy chain
        assert r.out == ref, (r.rid, r.out, ref)


def test_slots_recycled():
    cfg, model, params = _model("rwkv6-1.6b")
    rng = np.random.RandomState(1)
    reqs = [Request(rid=i, prompt=rng.randint(0, cfg.vocab_size, 3).tolist(),
                    max_new=4)
            for i in range(5)]
    batcher = ContinuousBatcher(model, params, n_slots=2, max_len=32)
    stats = batcher.run(reqs)  # 5 requests through 2 slots
    assert stats["requests"] == 5
    assert stats["tokens"] == 20
    assert all(r.done for r in reqs)


# ---------------------------------------------------------------------------
# Paged serving engine
# ---------------------------------------------------------------------------

def _paged(model, params, **kw):
    kw.setdefault("n_slots", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("chunk_max", 8)
    kw.setdefault("drain_every", 4)
    kw.setdefault("dtype", jnp.float32)
    return PagedServingEngine(model, params, **kw)


def _ragged_trace(cfg, seed=3, n=7):
    """Mixed prompt lengths, staggered arrivals, ragged max_new: forces
    interleaved admissions, completions and slot reuse."""
    rng = np.random.RandomState(seed)
    lens = [3, 9, 5, 13, 4, 11, 6]
    return [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size, lens[i]).tolist(),
                    max_new=3 + (i % 4) * 2, arrival=2 * i)
            for i in range(n)]


@pytest.mark.parametrize("arch", ["gemma-2b", "rwkv6-1.6b", "minicpm3-4b"])
def test_paged_engine_matches_sequential(arch):
    """Ragged interleaved serving through the paged engine is bit-identical
    to sequential single-request decode — full attention (paged pool), pure
    recurrence, and MLA latents (slot-dense) all covered."""
    cfg, model, params = _model(arch)
    reqs = _ragged_trace(cfg)
    refs = [_sequential_greedy(cfg, model, params, r.prompt, r.max_new)
            for r in reqs]
    eng = _paged(model, params)
    stats = eng.run(reqs)
    for r, ref in zip(reqs, refs):
        assert not r.rejected
        assert r.out == ref, (arch, r.rid, r.out, ref)
    assert stats["tokens"] == sum(len(ref) for ref in refs)
    # every slot freed, every page returned
    assert eng.kv.stats().pages_in_use == 0
    assert all(s is None for s in eng.slot_req)


def test_paged_and_dense_agree_on_identical_trace():
    cfg, model, params = _model()
    t1 = _ragged_trace(cfg, seed=4)
    t2 = [Request(r.rid, list(r.prompt), r.max_new, r.arrival) for r in t1]
    _paged(model, params).run(t1)
    ContinuousBatcher(model, params, n_slots=3, max_len=64, enc_len=0).run(t2)
    for a, b in zip(t1, t2):
        assert a.out == b.out, (a.rid, a.out, b.out)


def test_paged_engine_sync_cadence_and_counters():
    """Host syncs are bounded by the drain cadence (one per block), while
    the dense batcher syncs every tick; byte counters are populated."""
    cfg, model, params = _model()
    rng = np.random.RandomState(0)
    mk = lambda: [Request(rid=i,
                          prompt=rng.randint(0, cfg.vocab_size, 5).tolist(),
                          max_new=8) for i in range(4)]
    eng = _paged(model, params, n_slots=4, drain_every=4)
    ps = eng.run(mk())
    assert ps["host_syncs"] * 4 <= ps["ticks"] + 4  # ~1 sync per 4 ticks
    assert ps["bytes_to_host"] > 0 and ps["bytes_to_device"] > 0
    assert 0.0 <= ps["prefill_stall_fraction"] <= 1.0
    assert ps["tick_ms_p50"] > 0

    dense = ContinuousBatcher(model, params, n_slots=4, max_len=64,
                              enc_len=0)
    ds = dense.run(mk())
    assert ds["host_syncs"] >= ds["ticks"]  # the failure mode being fixed
    assert ds["bytes_to_host"] > 0


def test_oversized_requests_rejected_not_wedged():
    """A request that can never fit must be rejected by both engines while
    later requests still get served (no head-of-line blocking)."""
    cfg, model, params = _model()
    rng = np.random.RandomState(2)

    def mk():
        return [
            Request(rid=0, prompt=rng.randint(0, cfg.vocab_size, 60).tolist(),
                    max_new=30),  # 60 + 30 + 1 > max_len=64 -> reject
            Request(rid=1, prompt=rng.randint(0, cfg.vocab_size, 4).tolist(),
                    max_new=4),
        ]

    for stats, reqs in [
        (lambda r: _paged(model, params).run(r), mk()),
        (lambda r: ContinuousBatcher(model, params, n_slots=2, max_len=64,
                                     enc_len=0).run(r), mk()),
    ]:
        rs = reqs
        out = stats(rs)
        assert rs[0].rejected and rs[0].done
        assert not rs[1].rejected and len(rs[1].out) == 4
        assert out["rejected"] == 1


def test_admission_scans_past_blocked_head():
    """Paged admission is whole-queue: a request too big for the *currently
    free* pages must not block a small one behind it."""
    cfg, model, params = _model()
    rng = np.random.RandomState(5)
    big = [Request(rid=i, prompt=rng.randint(0, cfg.vocab_size, 40).tolist(),
                   max_new=8, arrival=0) for i in range(3)]
    small = Request(rid=99, prompt=rng.randint(0, cfg.vocab_size, 3).tolist(),
                    max_new=3, arrival=0)
    # 2 slots, pool sized so two 49-token reservations exhaust it
    eng = _paged(model, params, n_slots=2, max_len=64, page_size=8)
    eng.run(big + [small])
    assert all(not r.rejected for r in big + [small])
    assert len(small.out) == 3  # admitted out of order, not starved


def test_enc_len_single_parameter():
    """enc_len is configured once on the batcher, not hardcoded per call."""
    cfg, model, params = _model()
    b = ContinuousBatcher(model, params, n_slots=2, max_len=32, enc_len=0)
    assert b.enc_len == 0
    rng = np.random.RandomState(7)
    reqs = [Request(rid=0, prompt=rng.randint(0, cfg.vocab_size, 4).tolist(),
                    max_new=3)]
    b.run(reqs)
    assert len(reqs[0].out) == 3
