"""Continuous-batching serving: ragged slot occupancy must reproduce the
sequential single-request decode exactly (greedy tokens)."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import ContinuousBatcher, Request
from repro.models import LanguageModel


def _model(arch="gemma-2b"):
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    cfg = mod.smoke().scaled(compute_dtype="float32")
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _sequential_greedy(cfg, model, params, prompt, max_new):
    cache = model.init_cache(1, 64, enc_len=8, dtype=jnp.float32)
    logits = None
    for i, tok in enumerate(prompt):
        t = jnp.asarray([[tok]], jnp.int32)
        logits, cache = model.decode_step(params, t, cache,
                                          jnp.asarray([i], jnp.int32))
    out = []
    cur = int(jnp.argmax(logits[0]))
    pos = len(prompt)
    for _ in range(max_new):
        out.append(cur)
        logits, cache = model.decode_step(
            params, jnp.asarray([[cur]], jnp.int32), cache,
            jnp.asarray([pos], jnp.int32))
        cur = int(jnp.argmax(logits[0]))
        pos += 1
    return out


def test_continuous_batching_matches_sequential():
    cfg, model, params = _model()
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, 5).tolist() for _ in range(3)]
    max_new = 6

    refs = [_sequential_greedy(cfg, model, params, p, max_new)
            for p in prompts]

    batcher = ContinuousBatcher(model, params, n_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    stats = batcher.run(reqs)
    assert stats["tokens"] == 3 * max_new
    for r, ref in zip(reqs, refs):
        # first emitted token is argmax after prefill == ref[0]; subsequent
        # tokens follow the same greedy chain
        assert r.out == ref, (r.rid, r.out, ref)


def test_slots_recycled():
    cfg, model, params = _model("rwkv6-1.6b")
    rng = np.random.RandomState(1)
    reqs = [Request(rid=i, prompt=rng.randint(0, cfg.vocab_size, 3).tolist(),
                    max_new=4)
            for i in range(5)]
    batcher = ContinuousBatcher(model, params, n_slots=2, max_len=32)
    stats = batcher.run(reqs)  # 5 requests through 2 slots
    assert stats["requests"] == 5
    assert stats["tokens"] == 20
    assert all(r.done for r in reqs)
