"""AssetSelection: factories, combinators, closures, CLI parsing and the
legacy-``targets`` coercion shared by planner, coordinator and dryrun."""
import pytest

from repro.core import AssetGraph, AssetSelection, asset


def diamond():
    """fetch -> parse -> {stats, index} -> report, with tags/groups."""
    fetch = asset(name="fetch", tags={"group": "ingest", "team": "crawl"})(
        lambda ctx: 0)
    parse = asset(name="parse", deps=("fetch",),
                  tags={"group": "ingest"})(lambda ctx, fetch: 0)
    stats = asset(name="stats", deps=("parse",),
                  tags={"group": "analytics"})(lambda ctx, parse: 0)
    index = asset(name="index", deps=("parse",),
                  tags={"group": "analytics", "team": "crawl"})(
        lambda ctx, parse: 0)
    report = asset(name="report", deps=("stats", "index"))(
        lambda ctx, stats, index: 0)
    return AssetGraph([fetch, parse, stats, index, report])


G = diamond()


def test_assets_and_all():
    assert AssetSelection.assets("parse", "stats").resolve(G) == [
        "parse", "stats"]
    assert AssetSelection.all().resolve(G) == sorted(G.names())


def test_unknown_asset_raises_with_catalog():
    with pytest.raises(ValueError, match="unknown asset.*nope.*available"):
        AssetSelection.assets("nope").resolve(G)


def test_tag_and_group_filters():
    assert AssetSelection.tag("team", "crawl").resolve(G) == [
        "fetch", "index"]
    assert AssetSelection.tag("team").resolve(G) == ["fetch", "index"]
    assert AssetSelection.group("ingest").resolve(G) == ["fetch", "parse"]
    assert AssetSelection.tag("team", "nobody").resolve(G) == []


def test_closures():
    assert AssetSelection.assets("parse").downstream().resolve(G) == [
        "index", "parse", "report", "stats"]
    assert AssetSelection.assets("parse").downstream(
        include_self=False).resolve(G) == ["index", "report", "stats"]
    assert AssetSelection.assets("report").upstream().resolve(G) == \
        sorted(G.names())
    assert AssetSelection.assets("stats").upstream().resolve(G) == [
        "fetch", "parse", "stats"]


def test_set_operators():
    ingest = AssetSelection.group("ingest")
    crawl = AssetSelection.tag("team", "crawl")
    assert (ingest | crawl).resolve(G) == ["fetch", "index", "parse"]
    assert (ingest & crawl).resolve(G) == ["fetch"]
    assert (ingest - crawl).resolve(G) == ["parse"]
    assert (AssetSelection.all() - AssetSelection.assets("report")
            ).resolve(G) == ["fetch", "index", "parse", "stats"]


def test_parse_cli_syntax():
    assert AssetSelection.parse("stats").resolve(G) == ["stats"]
    assert AssetSelection.parse("parse+").resolve(G) == [
        "index", "parse", "report", "stats"]
    assert AssetSelection.parse("+stats").resolve(G) == [
        "fetch", "parse", "stats"]
    assert AssetSelection.parse("+index+").resolve(G) == [
        "fetch", "index", "parse", "report"]
    assert AssetSelection.parse("*").resolve(G) == sorted(G.names())
    assert AssetSelection.parse("tag:team=crawl").resolve(G) == [
        "fetch", "index"]
    assert AssetSelection.parse("tag:team").resolve(G) == ["fetch", "index"]
    assert AssetSelection.parse("group:analytics").resolve(G) == [
        "index", "stats"]
    # comma/whitespace-separated clauses union
    assert AssetSelection.parse("fetch, stats+").resolve(G) == [
        "fetch", "report", "stats"]
    assert AssetSelection.parse("fetch stats").resolve(G) == [
        "fetch", "stats"]


def test_parse_rejects_garbage():
    with pytest.raises(ValueError, match="empty selection"):
        AssetSelection.parse("   ")
    with pytest.raises(ValueError, match="bad selection clause"):
        AssetSelection.parse("a++b")


def test_coerce_legacy_spellings():
    assert AssetSelection.coerce(None).resolve(G) == sorted(G.names())
    assert AssetSelection.coerce([]).resolve(G) == sorted(G.names())
    assert AssetSelection.coerce(["stats", "fetch"]).resolve(G) == [
        "fetch", "stats"]
    assert AssetSelection.coerce("parse+").resolve(G) == [
        "index", "parse", "report", "stats"]
    sel = AssetSelection.group("ingest")
    assert AssetSelection.coerce(sel) is sel
    with pytest.raises(TypeError, match="cannot coerce"):
        AssetSelection.coerce(42)
    with pytest.raises(TypeError, match="must be strings"):
        AssetSelection.coerce([1, 2])


def test_repr_round_trips_visually():
    sel = (AssetSelection.group("ingest")
           | AssetSelection.assets("report")).downstream()
    assert "ingest" in repr(sel) and "downstream" in repr(sel)


def test_graph_downstream_upstream_helpers():
    assert G.downstream("parse") == {"stats", "index", "report"}
    assert G.downstream("report") == set()
    assert G.children("parse") == ("stats", "index")
    assert G.upstream("report") == {"fetch", "parse", "stats", "index"}
    assert G.upstream("fetch") == set()
