"""Orchestrator behaviour: DAG scheduling, caching, retries/failover,
straggler speculation, cost accounting, partitions."""
import numpy as np
import pytest

from repro.core import (AssetGraph, ComputeProfile, CostModel,
                        DynamicClientFactory, MessageReader,
                        MultiPartitions, Objective, RetryPolicy,
                        RunCoordinator, StaticPartitions,
                        TimeWindowPartitions, asset, default_catalog)
from repro.core.platforms import Platform


def make_factory(objective=None, seed=0, sim_time_scale=0.0, catalog=None):
    return DynamicClientFactory(
        catalog or default_catalog(), CostModel(),
        objective or Objective.balanced(), sim_seed=seed,
        sim_time_scale=sim_time_scale)


def nofail_factory(objective=None):
    """For tests of pure mechanics: run_ids are random uuids, so injected
    failures would be flaky by design — turn injection off."""
    from repro.core.clients import SimulatedClusterClient

    return DynamicClientFactory(
        default_catalog(), CostModel(), objective or Objective.balanced(),
        client_builder=lambda p: SimulatedClusterClient(
            p, failure_rate=0.0, preemption_rate=0.0))


def test_time_window_partitions():
    p = TimeWindowPartitions("2023-10", "2024-03")
    assert p.keys() == ["2023-10", "2023-11", "2023-12",
                        "2024-01", "2024-02", "2024-03"]


def test_multi_partitions_cross_product():
    p = MultiPartitions(dims=(
        ("time", TimeWindowPartitions("2024-01", "2024-02")),
        ("domain", StaticPartitions(("shard-0", "shard-1"))),
    ))
    assert len(p.keys()) == 4
    assert p.split("2024-01/shard-1") == {"time": "2024-01",
                                          "domain": "shard-1"}


def test_dag_topo_and_cycle_detection():
    a = asset(name="a")(lambda ctx: 1)
    b = asset(name="b", deps=("a",))(lambda ctx, a: a + 1)
    c = asset(name="c", deps=("a", "b"))(lambda ctx, a, b: a + b)
    g = AssetGraph([a, b, c])
    assert g.topo_order() == ["a", "b", "c"]
    bad = AssetGraph([
        asset(name="x", deps=("y",))(lambda ctx, y: y),
        asset(name="y", deps=("x",))(lambda ctx, x: x),
    ])
    with pytest.raises(ValueError, match="cycle"):
        bad.topo_order()


def test_end_to_end_materialize_with_deps():
    """Pure dependency mechanics — fault injection off (run_ids are random
    uuids, so injected failures would make this flaky by design)."""
    from repro.core.clients import SimulatedClusterClient

    calls = []

    @asset(name="up", compute=ComputeProfile(work_chip_hours=0.01))
    def up(ctx):
        calls.append("up")
        return 21

    @asset(name="down", deps=("up",),
           compute=ComputeProfile(work_chip_hours=0.01))
    def down(ctx, up):
        calls.append("down")
        return up * 2

    coord = RunCoordinator(AssetGraph([up, down]), nofail_factory())
    report = coord.materialize(["down"])
    assert report.ok
    assert coord.store.get("down", "__all__") == 42
    assert calls == ["up", "down"]


def test_caching_skips_fresh_materializations():
    n_runs = [0]

    @asset(name="cached_asset", compute=ComputeProfile(work_chip_hours=0.01))
    def cached_asset(ctx):
        n_runs[0] += 1
        return n_runs[0]

    g = AssetGraph([cached_asset])
    coord = RunCoordinator(g, nofail_factory())
    coord.materialize(["cached_asset"])
    # second run through the same coordinator: fingerprint unchanged -> skip
    report2 = coord.materialize(["cached_asset"])
    assert n_runs[0] == 1
    assert report2.records[0].cached


def test_partitioned_fan_in():
    parts = StaticPartitions(("p0", "p1", "p2"))

    @asset(name="shards", partitions=parts,
           compute=ComputeProfile(work_chip_hours=0.005))
    def shards(ctx):
        return int(ctx.partition_key[1:]) + 1

    @asset(name="merged", deps=("shards",),
           compute=ComputeProfile(work_chip_hours=0.005))
    def merged(ctx, shards):
        assert isinstance(shards, dict) and len(shards) == 3
        return sum(shards.values())

    coord = RunCoordinator(AssetGraph([shards, merged]), nofail_factory())
    report = coord.materialize(["merged"])
    assert report.ok
    assert coord.store.get("merged", "__all__") == 6


def test_retry_and_failover_on_flaky_platform():
    """A platform whose *actual* reliability is far worse than the catalog's
    belief must be retried then failed-over, and the failed attempts must
    still be billed (Fig 3 economics)."""
    from repro.core.clients import SimulatedClusterClient

    catalog = default_catalog()

    def builder(p):
        # reality: spot always fails; catalog still believes 22%
        return SimulatedClusterClient(
            p, seed=5, failure_rate=1.0 if p.name == "pod-spot" else 0.0,
            preemption_rate=0.0)

    factory = DynamicClientFactory(catalog, CostModel(),
                                   Objective.min_cost(),
                                   client_builder=builder)

    @asset(name="flaky", retry=RetryPolicy(max_attempts=5, backoff_s=0.0,
                                           failover_after=2),
           compute=ComputeProfile(work_chip_hours=10.0, min_chips=64))
    def flaky(ctx):
        return "done"

    reader = MessageReader()
    coord = RunCoordinator(AssetGraph([flaky]), factory, reader=reader)
    report = coord.materialize(["flaky"])
    assert report.ok
    rec = report.records[0]
    assert rec.status == "success"
    assert len(rec.attempts) >= 3  # 2 spot failures then failover
    assert any(a.status == "failure" for a in rec.attempts)
    assert rec.attempts[-1].platform != "pod-spot"
    assert reader.events(kind="FAILOVER")
    # failures billed
    failed_cost = sum(a.cost_usd for a in rec.attempts
                      if a.status == "failure")
    assert failed_cost > 0


def test_hard_failure_raises_after_max_attempts():
    catalog = {"pod-spot": Platform(
        **{**default_catalog()["pod-spot"].__dict__, "failure_rate": 1.0})}
    factory = make_factory(Objective.min_cost(), seed=9, catalog=catalog)

    @asset(name="doomed", retry=RetryPolicy(max_attempts=2, backoff_s=0.0),
           compute=ComputeProfile(work_chip_hours=10.0, min_chips=64))
    def doomed(ctx):
        return 1

    coord = RunCoordinator(AssetGraph([doomed]), factory)
    with pytest.raises(RuntimeError, match="failed after 2 attempts"):
        coord.materialize(["doomed"])


def test_straggler_speculation():
    """The cheapest platform straggles 50x; after enough partitions finish on
    the healthy one, the coordinator must speculatively re-dispatch and win."""
    from repro.core.clients import SimulatedClusterClient

    catalog = default_catalog()

    def builder(p):
        # partition p7 straggles 200x on spot (a sick node holding one shard)
        return SimulatedClusterClient(
            p, seed=1, sim_time_scale=3e-5, failure_rate=0.0,
            preemption_rate=0.0,
            duration_bias=lambda ctx: (
                200.0 if (ctx.partition_key == "p7"
                          and p.name == "pod-spot") else 1.0))

    parts = StaticPartitions(tuple(f"p{i}" for i in range(8)))

    @asset(name="uneven", partitions=parts,
           compute=ComputeProfile(work_chip_hours=80.0, min_chips=64))
    def uneven(ctx):
        return ctx.partition_key

    reader = MessageReader()
    factory = DynamicClientFactory(catalog, CostModel(),
                                   Objective.min_cost(),
                                   client_builder=builder)
    coord = RunCoordinator(AssetGraph([uneven]), factory, reader=reader,
                           straggler_factor=2.0, straggler_min_s=0.005,
                           max_concurrent=8)
    report = coord.materialize(["uneven"])
    assert report.ok
    # min_cost picks pod-spot (believed cheap) -> it straggles -> speculation
    assert reader.events(kind="SPECULATE"), "no speculative re-dispatch"
    spec_wins = [a for r in report.records for a in r.attempts
                 if a.speculative and a.status == "success"]
    assert spec_wins, "speculative twin never won"


def test_cost_model_prefers_cheap_for_light_and_fast_for_deadline():
    light = ComputeProfile(work_chip_hours=0.5, speedup_class="light")
    heavy = ComputeProfile(work_chip_hours=2000.0, speedup_class="scan")
    a_light = asset(name="l", compute=light)(lambda ctx: 0)
    a_heavy = asset(name="h", compute=heavy)(lambda ctx: 0)

    f_cost = make_factory(Objective.min_cost())
    f_time = make_factory(Objective.min_time())
    p, _ = f_cost.choose(a_light)
    assert p.name in ("local", "pod-spot")  # cheapest feasible
    p, _ = f_time.choose(a_heavy)
    assert p.kind in ("premium", "multipod") or p.chips >= 256


def test_telemetry_outcome_counts():
    reader = MessageReader()
    reader.emit("r", "a", "p", "pod-spot", "SUCCESS", duration_s=1.0)
    reader.emit("r", "a", "p", "pod-spot", "FAILURE")
    reader.emit("r", "a", "p", "pod-spot", "FAILURE", failure_kind="preemption")
    reader.emit("r", "a", "p", "pod-premium", "SUCCESS", duration_s=2.0)
    counts = reader.outcome_counts()
    # preemptions get their own bucket instead of inflating "failure"
    assert counts["pod-spot"] == {"success": 1, "failure": 1,
                                  "preemption": 1, "cancelled": 0}
    assert counts["pod-premium"]["preemption"] == 0
    assert np.isclose(reader.median_duration("a"), 1.5)
