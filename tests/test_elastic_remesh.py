"""Elastic scaling: training survives losing half the pool.

Train sharded on a 4-device (2x2) mesh -> checkpoint -> restart on a 2-device
(2x1) mesh with resharded restore (CheckpointManager.restore(sharding_fn=...))
-> continue training.  Loss trajectory must match the single-mesh run
(the checkpoint is mesh-independent: host arrays + re-put under new
shardings).  Run in subprocesses (forced host device counts).
"""
import subprocess
import sys
import textwrap


def run_sub(code: str, devices: int, timeout: int = 560) -> str:
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices}")
        {textwrap.indent(textwrap.dedent(code), '        ').strip()}
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, f"OUT:{r.stdout[-2000:]}\nERR:{r.stderr[-3000:]}"
    return r.stdout


TRAIN_SNIPPET = """
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs.gemma_2b import smoke
from repro.models import LanguageModel
from repro.optim import AdamW, OptConfig
from repro.checkpoint import CheckpointManager
from repro.data import TokenDataset
from repro.distributed.sharding import MeshInfo, use_mesh_info

def build():
    cfg = smoke().scaled(compute_dtype="float32")
    model = LanguageModel(cfg)
    opt = AdamW(OptConfig(peak_lr=3e-3, warmup_steps=2, decay_steps=20))
    data = TokenDataset(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    return cfg, model, opt, data

def step_fn(model, opt):
    def f(params, state, batch):
        (_, m), g = jax.value_and_grad(model.train_loss, has_aux=True)(
            params, batch)
        p2, s2, st = opt.update(g, state, params)
        return p2, s2, m["loss"]
    return jax.jit(f)
"""


def test_elastic_shrink_matches_straight_run(tmp_path):
    ck = str(tmp_path / "ck")
    # phase 1: 4 devices (2x2), 4 steps, save
    out1 = run_sub(TRAIN_SNIPPET + f"""
cfg, model, opt, data = build()
mesh = jax.make_mesh((2, 2), ("data", "model"))
info = MeshInfo(mesh)
with use_mesh_info(info), mesh:
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    axes = model.param_axes
    shardings = jax.tree.map(lambda v, ax: info.sharding(v.shape, ax),
                             params, axes)
    params = jax.device_put(params, shardings)
    state = opt.init(params)
    f = step_fn(model, opt)
    for s in range(4):
        batch = {{k: jnp.asarray(v) for k, v in data.batch(s).items()}}
        params, state, loss = f(params, state, batch)
mgr = CheckpointManager({ck!r}, async_write=False)
mgr.save(4, {{"params": params, "opt_state": state}})
print("PHASE1", float(loss))
""", devices=4)
    assert "PHASE1" in out1

    # phase 2: pool shrinks to 2 devices (2x1); resharded restore + 2 steps
    out2 = run_sub(TRAIN_SNIPPET + f"""
cfg, model, opt, data = build()
mesh = jax.make_mesh((2, 1), ("data", "model"))
info = MeshInfo(mesh)
mgr = CheckpointManager({ck!r}, async_write=False)
with use_mesh_info(info), mesh:
    like_p = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    axes = model.param_axes
    flatmap = {{}}
    import jax.tree_util as jtu
    for path, ax in jtu.tree_flatten_with_path(
            axes, is_leaf=lambda a: isinstance(a, tuple)
            and all(isinstance(e, (str, type(None))) for e in a))[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flatmap["params/" + key] = ax
    def sharding_fn(key):
        ax = flatmap.get(key)
        if ax is None:  # opt moments mirror params; step is replicated
            ax = flatmap.get(key.replace("opt_state/m/", "params/")
                             .replace("opt_state/v/", "params/"))
        shape = None
        if ax is None:
            return info.sharding((), ())
        return None  # fall back to default put below
    like = {{"params": like_p, "opt_state": jax.eval_shape(opt.init, like_p)}}
    step, tree = mgr.restore_latest(like)
    params, state = tree["params"], tree["opt_state"]
    shardings = jax.tree.map(lambda v, ax: info.sharding(v.shape, ax),
                             params, axes)
    params = jax.device_put(params, shardings)
    f = step_fn(model, opt)
    losses = []
    for s in range(step, step + 2):
        batch = {{k: jnp.asarray(v) for k, v in data.batch(s).items()}}
        params, state, loss = f(params, state, batch)
        losses.append(float(loss))
print("PHASE2", losses)
""", devices=2)
    assert "PHASE2" in out2

    # reference: straight 6-step single-device run
    out3 = run_sub(TRAIN_SNIPPET + """
cfg, model, opt, data = build()
params = model.init(jax.random.PRNGKey(0))
state = opt.init(params)
f = step_fn(model, opt)
losses = []
for s in range(6):
    batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
    params, state, loss = f(params, state, batch)
    losses.append(float(loss))
print("REF", losses[-2:])
""", devices=1)
    ref = eval(out3.split("REF", 1)[1].strip())
    got = eval(out2.split("PHASE2", 1)[1].strip())
    for a, b in zip(got, ref):
        assert abs(a - b) < 2e-3, (got, ref)
