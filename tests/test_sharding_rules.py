"""MeshInfo logical-axis resolution: divisibility fallback, axis reuse,
spec construction — the invariants the whole distribution layer rests on."""
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import MeshInfo, constrain


class FakeMesh:
    """Just enough of a Mesh for MeshInfo's spec logic (no devices)."""

    def __init__(self, shape: dict):
        self.axis_names = tuple(shape)
        self.devices = np.empty(tuple(shape.values()), dtype=object)


def info(**shape) -> MeshInfo:
    return MeshInfo(FakeMesh(shape))  # type: ignore[arg-type]


def test_batch_spreads_over_pod_and_data():
    i = info(pod=2, data=16, model=16)
    assert i.spec((256, 4096), ("batch", "seq_act")) == P(("pod", "data"),
                                                          "model")


def test_divisibility_fallback_drops_axis():
    i = info(data=16, model=16)
    # 8 kv heads can't shard over 16-way model: dropped
    assert i.spec((32, 1024, 8, 128),
                  ("batch", None, "kv_heads", None)) == P("data")
    # 32 kv heads can
    assert i.spec((32, 1024, 32, 128),
                  ("batch", None, "kv_heads", None)) == P("data", None,
                                                          "model")


def test_axis_used_once_per_tensor():
    i = info(data=16, model=16)
    # both dims want "model": first one wins, second drops
    spec = i.spec((64, 64), ("heads", "mlp"))
    assert spec == P("model")


def test_batch_one_cannot_shard():
    i = info(data=16, model=16)
    assert i.spec((1, 524288), ("batch", "kv_seq")) == P(None, "model")


def test_partial_divisibility_multi_axis():
    i = info(pod=2, data=16, model=16)
    # batch 16: divisible by pod(2) then pod*data(32)? 16 % 32 != 0 -> pod only
    assert i.spec((16, 8), ("batch", None)) == P("pod")
    # batch 64: 64 % 2 == 0, 64 % 32 == 0 -> both
    assert i.spec((64, 8), ("batch", None)) == P(("pod", "data"))


def test_constrain_noop_without_mesh():
    x = jax.numpy.ones((4, 4))
    y = constrain(x, "batch", "seq_act")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_trailing_nones_trimmed():
    i = info(data=16, model=16)
    spec = i.spec((32, 64, 64, 64), ("batch", None, None, None))
    assert spec == P("data")
