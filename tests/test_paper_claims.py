"""Faithful-reproduction gates: the paper's own claims, validated against the
calibrated platform catalog + orchestrator (EXPERIMENTS.md §Claims)."""
import pytest

from benchmarks.table1_cost import TABLE1, headline_claims, per_cell_table


@pytest.fixture(scope="module")
def claims():
    return headline_claims(n_seeds=16)


def test_cost_reduction_vs_dbr_at_least_40pct(claims):
    """Paper: '40% cost reduction compared to DBR' (Table-1 basis; the
    simulated basis adds failure/retry billing + duration jitter and is
    asserted looser)."""
    assert claims["cost_reduction_vs_premium_table_basis"] >= 0.40
    assert claims["cost_reduction_vs_premium_simulated"] >= 0.32


def test_savings_over_300_per_run(claims):
    """Paper: 'over 300 euros saved per pipeline run'."""
    assert claims["savings_usd_per_run"] >= 300.0


def test_12pct_performance_improvement(claims):
    """Paper: '12% performance improvement over EMR' — reproduced in the
    platform-tuning reading (§6 tuning narrative; see DESIGN.md)."""
    assert abs(claims["tuning_improvement_vs_untuned_spot"] - 0.12) < 0.01


def test_table1_heavy_rows_match():
    """edges (the cost-dominant asset): model vs Table 1 within 10%
    duration / 10% cost on both platforms."""
    rows = per_cell_table()
    for asset_name, plat, ref_h, ref_usd in TABLE1:
        if asset_name != "edges":
            continue
        row = next(r for r in rows
                   if r["asset"] == asset_name and r["platform"] == plat)
        assert abs(row["duration_h"] - ref_h) / ref_h < 0.10, (plat, row)
        assert abs(row["total_usd"] - ref_usd) / ref_usd < 0.10, (plat, row)


def test_reliability_gap_spot_vs_premium():
    """Fig 3: the cheap platform fails more and needs more attempts
    (expected ratio (1/0.70)/(1/0.88) ~ 1.26 at the calibrated rates)."""
    from benchmarks.fig3_reliability import run
    out = run(n_seeds=14)
    assert out["failure_rate"]["pod-spot"] > out["failure_rate"]["pod-premium"]
    assert out["trial_ratio_spot_over_premium"] > 1.08


def test_fig6_premium_faster_on_heavy_steps():
    from benchmarks.fig6_durations import run
    table = run(n_seeds=5)
    assert (table["edges@pod-spot"]["median_h"]
            > 1.25 * table["edges@pod-premium"]["median_h"])


def test_fig4_effort_gap():
    """Fig 4: 'almost double the number of trial runs for EMR' before
    production stability, with far more cumulative config changes."""
    from benchmarks.fig4_effort import run
    out = run(n_seeds=30)
    assert 1.5 < out["trial_ratio_spot_over_premium"] < 3.0
    assert (out["pod-spot"]["mean_changes"]
            > 2.0 * out["pod-premium"]["mean_changes"])
