"""Fault tolerance end-to-end: training survives a simulated preemption and
resumes from the latest committed checkpoint; loss decreases on the
structured synthetic stream."""
import subprocess
import sys

import pytest

from repro.launch.train import train


def test_loss_decreases_smoke():
    out = train(arch="gemma-2b", smoke=True, steps=30, global_batch=4,
                seq_len=64, peak_lr=5e-3, log_every=5, ckpt_dir=None)
    assert out["first_loss"] is not None
    assert out["final_loss"] < out["first_loss"] - 0.3, out["history"]


def test_preemption_resume_equivalence(tmp_path):
    """train 12 steps straight == train 8, preempt, resume to 12 (same data,
    same seeds) — the checkpoint carries the full optimizer state."""
    d1 = str(tmp_path / "straight")
    ref = train(arch="h2o-danube-1.8b", smoke=True, steps=12, global_batch=2,
                seq_len=32, save_every=4, log_every=12, ckpt_dir=d1)

    d2 = str(tmp_path / "resumed")
    with pytest.raises(SystemExit) as e:
        train(arch="h2o-danube-1.8b", smoke=True, steps=12, global_batch=2,
              seq_len=32, save_every=4, log_every=12, ckpt_dir=d2,
              preempt_at=8)
    assert e.value.code == 17
    res = train(arch="h2o-danube-1.8b", smoke=True, steps=12, global_batch=2,
                seq_len=32, save_every=4, log_every=12, ckpt_dir=d2,
                resume=True)
    assert abs(res["final_loss"] - ref["final_loss"]) < 1e-3, \
        (res["final_loss"], ref["final_loss"])


def test_cli_driver_runs(tmp_path):
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "rwkv6-1.6b",
           "--steps", "4", "--batch", "2", "--seq", "32", "--log-every", "2",
           "--ckpt-dir", str(tmp_path / "ck")]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=400,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done:" in r.stdout
