"""The paper's §5 pipeline: asset-level correctness of the web-graph mining
(dedup, weights, domain aggregation) + end-to-end orchestrated run."""
import numpy as np

from repro.data import commoncrawl as cc


CFG = cc.CrawlConfig(n_domains=16, n_pages_per_domain=3, n_seed=12,
                     max_links=5, tokens_per_page=16)


def test_nodes_deduped_and_bounded():
    n = cc.nodes_asset("2023-10", "s0", CFG)
    seeds = n["seed_pages"]
    assert len(np.unique(seeds)) == len(seeds)
    assert len(seeds) <= CFG.n_seed
    assert seeds.max() < CFG.n_domains * CFG.n_pages_per_domain


def test_edges_only_from_seed_pages():
    n = cc.nodes_asset("2023-10", "s0", CFG)
    e = cc.edges_asset("2023-10", "s0", n, CFG)
    assert set(np.unique(e["src"])) <= set(n["seed_pages"].tolist())
    assert len(e["src"]) == len(e["dst"]) == len(e["weight"])
    assert np.all(e["weight"] >= 0) and np.all(e["weight"] <= 1)


def test_graph_deduplicates_and_sums_weights():
    n = cc.nodes_asset("2023-10", "s0", CFG)
    e = cc.edges_asset("2023-10", "s0", n, CFG)
    g = cc.graph_asset(n, e)
    pairs = list(zip(g["src"].tolist(), g["dst"].tolist()))
    assert len(set(pairs)) == len(pairs), "graph edges must be unique"
    np.testing.assert_allclose(g["weight"].sum(), e["weight"].sum(),
                               rtol=1e-5)


def test_graph_aggr_preserves_mass_and_domains():
    n = cc.nodes_asset("2023-10", "s0", CFG)
    e = cc.edges_asset("2023-10", "s0", n, CFG)
    g = cc.graph_asset(n, e)
    a = cc.graph_aggr_asset(g, CFG)
    np.testing.assert_allclose(a["weight"].sum(), g["weight"].sum(),
                               rtol=1e-4)
    assert a["src_domain"].max() < CFG.n_domains
    assert a["dst_domain"].max() < CFG.n_domains


def test_determinism_across_processes():
    a1 = cc.edges_asset("2023-11", "s1",
                        cc.nodes_asset("2023-11", "s1", CFG), CFG)
    a2 = cc.edges_asset("2023-11", "s1",
                        cc.nodes_asset("2023-11", "s1", CFG), CFG)
    np.testing.assert_array_equal(a1["src"], a2["src"])
    np.testing.assert_allclose(a1["weight"], a2["weight"])


def test_partitions_differ():
    n1 = cc.nodes_asset("2023-10", "s0", CFG)
    n2 = cc.nodes_asset("2023-11", "s0", CFG)
    assert not np.array_equal(n1["seed_pages"], n2["seed_pages"])


def test_end_to_end_orchestrated(tmp_path):
    from benchmarks.cc_pipeline import run_policy
    from repro.core import MultiPartitions, StaticPartitions
    parts = MultiPartitions(dims=(
        ("time", StaticPartitions(("2023-10",))),
        ("domain", StaticPartitions(("shard-0",))),
    ))
    report, reader = run_policy("orchestrated", seed=4, partitions=parts)
    assert report.ok
    assert reader.events(kind="MATERIALIZE")
    # edges must dominate the bill (Fig 5 shape)
    costs = report.by_asset_cost()
    assert costs["edges"] > 5 * (costs["nodes"] + costs["graph_aggr"])