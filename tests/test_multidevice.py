"""Multi-device semantics on a small forced-host-device mesh, run in
subprocesses so the main test process keeps a single device (the dry-run is
the only place that forces 512).

Covers: sharded-vs-single-device numerics parity for the train loss (incl.
the shard_map MoE path), gradient-compression error feedback, and the GPipe
pipeline vs the sequential reference.
"""
import os
import subprocess
import sys
import textwrap



def run_sub(code: str, devices: int = 8, timeout: int = 560) -> str:
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices}")
        {textwrap.indent(textwrap.dedent(code), '        ').strip()}
    """)
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    if "JAX_PLATFORMS" in os.environ:  # don't probe TPU/GPU backends in subs
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:{r.stdout[-2000:]}\nERR:{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_loss_matches_single_device_moe():
    """deepseek-v2 smoke (MoE+MLA) on a 2x2 mesh == unsharded, exercising the
    shard_map dispatch path against the dense path."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.deepseek_v2_236b import smoke
        from repro.models import LanguageModel
        from repro.models import moe as moe_mod
        from repro.distributed.sharding import MeshInfo, use_mesh_info
        from repro.launch.specs import param_specs, batch_specs

        moe_mod._SMALL_T = 16  # force the shard_map path for tiny smoke shapes
        cfg = smoke().scaled(compute_dtype="float32", n_experts=8,
                             d_model=64)
        model = LanguageModel(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        B, S = 4, 32
        batch = {
            "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S))),
            "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S))),
            "weights": jnp.ones((B, S), jnp.float32),
        }
        ref, _ = jax.jit(model.train_loss)(params, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        info = MeshInfo(mesh)
        with use_mesh_info(info), mesh:
            axes = model.param_axes
            shardings = jax.tree.map(
                lambda v, ax: info.sharding(v.shape, ax), params, axes)
            params_s = jax.device_put(params, shardings)
            batch_s = jax.device_put(batch, {
                k: info.sharding(v.shape, ("batch", "seq_act"))
                for k, v in batch.items()})
            sharded, _ = jax.jit(model.train_loss)(params_s, batch_s)
        np.testing.assert_allclose(float(ref), float(sharded), rtol=2e-4)
        print("PARITY OK", float(ref), float(sharded))
    """)
    assert "PARITY OK" in out


def test_sharded_loss_matches_single_device_gqa():
    """qwen smoke (GQA + expanded-KV path) sharded == unsharded."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.qwen2_vl_72b import smoke
        from repro.models import LanguageModel
        from repro.distributed.sharding import MeshInfo, use_mesh_info

        cfg = smoke().scaled(compute_dtype="float32")
        model = LanguageModel(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.RandomState(1)
        B, S = 4, 64
        batch = {
            "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S))),
            "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S))),
            "weights": jnp.ones((B, S), jnp.float32),
        }
        ref, _ = jax.jit(model.train_loss)(params, batch)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        info = MeshInfo(mesh)
        with use_mesh_info(info), mesh:
            axes = model.param_axes
            shardings = jax.tree.map(
                lambda v, ax: info.sharding(v.shape, ax), params, axes)
            params_s = jax.device_put(params, shardings)
            sharded, _ = jax.jit(model.train_loss)(params_s, batch)
        np.testing.assert_allclose(float(ref), float(sharded), rtol=2e-4)
        print("PARITY OK")
    """)
    assert "PARITY OK" in out


def test_grad_compression_error_feedback():
    out = run_sub("""
        import inspect
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import compressed_psum
        try:
            from jax import shard_map
        except ImportError:
            from jax.experimental.shard_map import shard_map
        nocheck = ({"check_vma": False} if "check_vma" in
                   inspect.signature(shard_map).parameters
                   else {"check_rep": False})

        mesh = jax.make_mesh((4,), ("pod",))
        g_global = jax.random.normal(jax.random.PRNGKey(0), (4, 64))

        def f(g, e):
            m, ne = compressed_psum(g[0], "pod", e[0])
            return m[None], ne[None]

        e = jnp.zeros((4, 64))
        sm = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                       out_specs=(P("pod"), P("pod")), **nocheck)
        true_mean = jnp.mean(g_global, axis=0)
        # single round: bounded quantization error
        m, e1 = sm(g_global, e)
        err1 = float(jnp.max(jnp.abs(m[0] - true_mean)))
        scale = float(jnp.max(jnp.abs(g_global)) / 127.0)
        assert err1 <= scale + 1e-6, (err1, scale)
        # error feedback: summed estimates over repeated rounds of the SAME
        # gradient converge to the true mean (residual carrying)
        est_sum = jnp.zeros(64)
        e = jnp.zeros((4, 64))
        for _ in range(20):
            m, e = sm(g_global, e)
            est_sum = est_sum + m[0]
        avg = est_sum / 20
        np.testing.assert_allclose(np.asarray(avg), np.asarray(true_mean),
                                   atol=5e-3)
        print("COMPRESS OK", err1)
    """, devices=4)
    assert "COMPRESS OK" in out


def test_pipeline_matches_sequential():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply

        n_stages, n_micro, mb, d = 4, 6, 2, 8
        mesh = jax.make_mesh((n_stages,), ("model",))
        ks = jax.random.split(jax.random.PRNGKey(0), n_stages)
        params = {"w": jnp.stack([jax.random.normal(k, (d, d)) * 0.3
                                  for k in ks]),
                  "b": jnp.stack([jnp.ones((d,)) * 0.01] * n_stages)}
        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

        def stage_fn(p, h):
            return jnp.tanh(h @ p["w"] + p["b"])

        out = pipeline_apply(stage_fn, params, x, mesh, axis="model")
        ref = x
        for i in range(n_stages):
            p_i = jax.tree.map(lambda a: a[i], params)
            ref = jax.vmap(lambda m: stage_fn(p_i, m))(ref)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("PIPELINE OK")
    """, devices=4)
    assert "PIPELINE OK" in out


def test_small_mesh_dryrun_cell():
    """lower+compile a reduced arch on a 2x2 mesh end-to-end (the dry-run
    machinery itself, CI-scale)."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs.granite_moe_1b_a400m import smoke
        from repro.distributed.sharding import MeshInfo, use_mesh_info
        from repro.launch.specs import param_specs, batch_specs
        from repro.launch.dryrun import make_train_step, _opt_specs, shardings_of
        from repro.models import LanguageModel
        from repro.optim import AdamW, OptConfig
        from repro.configs.base import ShapeSpec

        cfg = smoke()
        shape = ShapeSpec("t", "train", 64, 4)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        info = MeshInfo(mesh)
        model = LanguageModel(cfg)
        opt = AdamW(OptConfig())
        with use_mesh_info(info), mesh:
            psds = param_specs(model, info)
            osds = _opt_specs(model, opt, info, psds)
            bsds = batch_specs(cfg, shape, info)
            fn = jax.jit(make_train_step(model, opt, shardings_of(psds)),
                         donate_argnums=(0, 1))
            compiled = fn.lower(psds, osds, bsds).compile()
        ca = compiled.cost_analysis()  # list[dict] before jax 0.6, dict after
        if isinstance(ca, list):
            ca = ca[0] if ca else {}
        print("COMPILED OK", ca.get("flops", 0) > 0)
    """, devices=4)
    assert "COMPILED OK" in out
