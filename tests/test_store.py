"""Content-addressed MaterializationStore: cross-run persistence,
fingerprint sensitivity, staleness resolution, warm-run/backfill/early-cutoff
semantics through the coordinator."""
import dataclasses

import pytest

from repro.core import (AssetGraph, ComputeProfile, CostModel,
                        DynamicClientFactory, MaterializationStore,
                        MessageReader, Objective, RunCoordinator,
                        StaticPartitions, Staleness, asset, code_version,
                        default_catalog, resolve_staleness, source_hash)


def nofail_factory(objective=None):
    from repro.core.clients import SimulatedClusterClient

    return DynamicClientFactory(
        default_catalog(), CostModel(), objective or Objective.balanced(),
        client_builder=lambda p: SimulatedClusterClient(
            p, failure_rate=0.0, preemption_rate=0.0))


def _coord(graph, store, reader=None):
    return RunCoordinator(graph, nofail_factory(), store=store,
                          reader=reader or MessageReader(),
                          enable_speculation=False)


# ------------------------------------------------------------ store basics
def test_store_round_trip_across_two_instances(tmp_path):
    """A second store instance on the same directory sees the first's
    materializations — records, values and freshness checks."""
    d = str(tmp_path / "store")
    s1 = MaterializationStore(d)
    fp = s1.fingerprint("1:abc", "p0", {"up[p0]": "deadbeef"})
    s1.put("a", "p0", {"rows": [1, 2, 3]}, fp, code_version="1:abc",
           upstream={"up[p0]": "deadbeef"}, meta={"platform": "pod-spot"})

    s2 = MaterializationStore(d)
    assert len(s2) == 1
    assert s2.get("a", "p0") == {"rows": [1, 2, 3]}
    assert s2.is_fresh("a", "p0", fp)
    rec = s2.record("a", "p0")
    assert rec["code_version"] == "1:abc"
    assert rec["upstream"] == {"up[p0]": "deadbeef"}
    assert rec["meta"]["platform"] == "pod-spot"
    # invalidation persists too
    s2.invalidate("a", "p0")
    assert MaterializationStore(d).record("a", "p0") is None


def test_identical_values_share_one_blob(tmp_path):
    d = str(tmp_path / "store")
    s = MaterializationStore(d)
    s.put("a", "p0", [1, 2], "fp-a", code_version="1:x")
    s.put("b", "p0", [1, 2], "fp-b", code_version="1:y")
    blobs = list((tmp_path / "store" / "blobs").iterdir())
    assert len(blobs) == 1  # content-addressed: one blob backs both records
    assert s.data_hash("a", "p0") == s.data_hash("b", "p0")


def test_in_memory_store_still_works():
    s = MaterializationStore()
    s.put("a", "p0", 42, "fp")
    assert s.get("a", "p0") == 42
    assert s.is_fresh("a", "p0", "fp") and not s.is_fresh("a", "p0", "other")
    with pytest.raises(KeyError):
        s.get("a", "p1")


# -------------------------------------------------- fingerprint sensitivity
def test_fingerprint_sensitivity_matrix():
    """hash(code version, partition, upstream data hashes): each input
    perturbs the fingerprint, a no-op reproduces it."""
    base = MaterializationStore.fingerprint("1:abc", "p0", {"u[p0]": "h1"})
    assert MaterializationStore.fingerprint(
        "1:abc", "p0", {"u[p0]": "h1"}) == base  # no-op
    assert MaterializationStore.fingerprint(
        "2:abc", "p0", {"u[p0]": "h1"}) != base  # version bump
    assert MaterializationStore.fingerprint(
        "1:def", "p0", {"u[p0]": "h1"}) != base  # source changed
    assert MaterializationStore.fingerprint(
        "1:abc", "p1", {"u[p0]": "h1"}) != base  # partition
    assert MaterializationStore.fingerprint(
        "1:abc", "p0", {"u[p0]": "h2"}) != base  # upstream data
    assert MaterializationStore.fingerprint(
        "1:abc", "p0", {"u[p0]": "h1", "v[p0]": "h3"}) != base  # new dep


def test_source_hash_tracks_function_body():
    def f(ctx):
        return 1

    def g(ctx):
        return 2

    def f2(ctx):
        return 1

    assert source_hash(f) != source_hash(g)
    assert source_hash(f) == source_hash(f)

    spec_v1 = asset(name="x", version="1")(f)
    spec_v2 = asset(name="x", version="2")(f)
    assert code_version(spec_v1) != code_version(spec_v2)
    assert code_version(spec_v1).startswith("1:")


def test_data_fingerprint_is_content_based():
    _, h1 = MaterializationStore.data_fingerprint({"a": [1, 2]})
    _, h2 = MaterializationStore.data_fingerprint({"a": [1, 2]})
    _, h3 = MaterializationStore.data_fingerprint({"a": [1, 3]})
    assert h1 == h2 != h3


# ---------------------------------------------------- staleness resolution
def _chain_graph(versions=("1", "1")):
    up = asset(name="up", version=versions[0],
               compute=ComputeProfile(work_chip_hours=0.01))(lambda ctx: 7)
    down = asset(name="down", deps=("up",), version=versions[1],
                 compute=ComputeProfile(work_chip_hours=0.01))(
        lambda ctx, up: up * 2)
    return AssetGraph([up, down])


def test_resolve_staleness_reasons(tmp_path):
    g = _chain_graph()
    store = MaterializationStore(str(tmp_path / "s"))

    st = resolve_staleness(g, store)
    assert st[("up", "__all__")] == Staleness(
        False, "never-materialized", st[("up", "__all__")].fingerprint)
    assert st[("down", "__all__")].reason == "upstream-stale:up[__all__]"

    _coord(g, store).materialize()
    st = resolve_staleness(g, store)
    assert all(v.fresh for v in st.values())

    # forced: everything stale regardless of records
    st = resolve_staleness(g, store, force=True)
    assert all(v.reason == "forced" for v in st.values())

    # code change on the upstream poisons the cone pessimistically
    g2 = _chain_graph(versions=("2", "1"))
    st = resolve_staleness(g2, store)
    assert st[("up", "__all__")].reason == "code-changed"
    assert st[("down", "__all__")].reason == "upstream-stale:up[__all__]"


def test_missing_upstream_record_forces_staleness(tmp_path):
    """A downstream record whose upstream record is gone must be stale —
    regression test for the old '?' placeholder that faked freshness."""
    g = _chain_graph()
    store = MaterializationStore(str(tmp_path / "s"))
    _coord(g, store).materialize()
    store.invalidate("up")
    st = resolve_staleness(g, store)
    assert st[("up", "__all__")].reason == "never-materialized"
    assert not st[("down", "__all__")].fresh
    # and through the coordinator: down's fingerprint recomputes only after
    # up re-materializes; identical data -> early cutoff, no down re-run
    rep = _coord(g, store).materialize()
    executed = [(r.asset, r.partition) for r in rep.records if not r.cached]
    assert executed == [("up", "__all__")]


# ------------------------------------------------ coordinator integration
def test_warm_run_executes_zero_tasks_across_processes(tmp_path):
    d = str(tmp_path / "s")
    runs = []

    def build():
        up = asset(name="up", partitions=StaticPartitions(("a", "b")),
                   compute=ComputeProfile(work_chip_hours=0.01))(
            lambda ctx: ctx.partition_key)
        down = asset(name="down", deps=("up",),
                     compute=ComputeProfile(work_chip_hours=0.01))(
            lambda ctx, up: runs.append("down") or sorted(up.values()))
        return AssetGraph([up, down])

    cold = _coord(build(), MaterializationStore(d)).materialize()
    assert cold.ok and not any(r.cached for r in cold.records)

    # new store instance + coordinator on the same directory: a fully warm
    # run executes nothing
    warm = _coord(build(), MaterializationStore(d)).materialize()
    assert warm.ok
    assert all(r.cached for r in warm.records)
    assert runs == ["down"]


def test_backfill_executes_exactly_the_stale_cone(tmp_path):
    """Invalidate one upstream partition with changed source data: only that
    partition's cone re-executes; sibling partitions stay cached."""
    d = str(tmp_path / "s")
    parts = StaticPartitions(("a", "b"))
    external = {"a": 1, "b": 1}  # external input, invisible to code hashes

    def build():
        up = asset(name="up", partitions=parts,
                   compute=ComputeProfile(work_chip_hours=0.01))(
            lambda ctx: external[ctx.partition_key])
        mid = asset(name="mid", deps=("up",), partitions=parts,
                    compute=ComputeProfile(work_chip_hours=0.01))(
            lambda ctx, up: up * 10)
        sink = asset(name="sink", deps=("mid",),
                     compute=ComputeProfile(work_chip_hours=0.01))(
            lambda ctx, mid: sum(mid.values()))
        return AssetGraph([up, mid, sink])

    store = MaterializationStore(d)
    assert _coord(build(), store).materialize().ok

    external["a"] = 2  # the source snapshot for partition 'a' changed
    store.invalidate("up", "a")
    rep = _coord(build(), MaterializationStore(d)).materialize()
    executed = sorted((r.asset, r.partition) for r in rep.records
                      if not r.cached)
    # sink consumes both mid partitions (fan-in), so it is in the cone
    assert executed == [("mid", "a"), ("sink", "__all__"), ("up", "a")]
    assert MaterializationStore(d).get("sink", "__all__") == 30


def test_early_cutoff_upstream_reproduces_identical_data(tmp_path):
    d = str(tmp_path / "s")
    g = _chain_graph()
    store = MaterializationStore(d)
    _coord(g, store).materialize()
    store.invalidate("up", "__all__")
    rep = _coord(g, store).materialize()
    executed = [(r.asset, r.partition) for r in rep.records if not r.cached]
    assert executed == [("up", "__all__")]  # down cut off: same bytes


def test_force_rebuilds_everything(tmp_path):
    g = _chain_graph()
    store = MaterializationStore(str(tmp_path / "s"))
    _coord(g, store).materialize()
    rep = _coord(g, store).materialize(force=True)
    assert not any(r.cached for r in rep.records)


def test_code_change_invalidates_only_its_cone(tmp_path):
    d = str(tmp_path / "s")
    parts = StaticPartitions(("a", "b"))

    def build(down_body):
        up = asset(name="up", partitions=parts,
                   compute=ComputeProfile(work_chip_hours=0.01))(
            lambda ctx: ctx.partition_key)
        down = asset(name="down", deps=("up",), partitions=parts,
                     compute=ComputeProfile(work_chip_hours=0.01))(down_body)
        return AssetGraph([up, down])

    def v1(ctx, up):
        return up + "!"

    def v2(ctx, up):
        return up + "?"

    store = MaterializationStore(d)
    assert _coord(build(v1), store).materialize().ok
    rep = _coord(build(v2), MaterializationStore(d)).materialize()
    executed = sorted((r.asset, r.partition) for r in rep.records
                      if not r.cached)
    assert executed == [("down", "a"), ("down", "b")]  # up untouched


def test_cache_telemetry(tmp_path):
    g = _chain_graph()
    store = MaterializationStore(str(tmp_path / "s"))
    reader = MessageReader()
    coord = _coord(g, store, reader=reader)
    coord.materialize(run_id="cold")
    coord.materialize(run_id="warm")
    cold = reader.cache_stats("cold")
    warm = reader.cache_stats("warm")
    assert cold == {"cache_hits": 0, "executed": 2,
                    "stale_reasons": {"never-materialized": 1,
                                      "upstream-stale": 1},
                    "hit_rate": 0.0}
    assert warm["cache_hits"] == 2 and warm["executed"] == 0
    assert warm["hit_rate"] == 1.0 and warm["stale_reasons"] == {}
    assert reader.events(kind="CACHE_HIT")


def test_store_record_survives_value_strip(tmp_path):
    """The persisted index never embeds values — only blob paths — and a
    reloaded record still resolves its value through the blob."""
    d = str(tmp_path / "s")
    s = MaterializationStore(d)
    s.put("a", "p0", {"big": list(range(100))}, "fp")
    rec = MaterializationStore(d).record("a", "p0")
    assert "value" not in rec and rec["path"].startswith("blobs/")
    assert MaterializationStore(d).get("a", "p0")["big"][-1] == 99


def test_staleness_is_frozen():
    st = Staleness(True, "fresh", "fp")
    with pytest.raises(dataclasses.FrozenInstanceError):
        st.fresh = False
