"""Crash consistency: run journal, fault injection, resume, store hardening.

The heart is the chaos sweep: kill the coordinator at *every* journal record
boundary (the record is durable, the action it describes may not have
happened), resume, and require — at each kill point — a completed run with
zero duplicate billing, spend exactly equal to an uninterrupted run of the
same run_id, byte-identical store contents, and rework bounded by the
in-flight frontier.
"""
import os
import warnings

import pytest

from repro.core import (AssetGraph, ClientFaults, ComputeProfile,
                        CoordinatorKilled, CostModel, DynamicClientFactory,
                        FaultPlan, JournalCorruption, JournalState,
                        MaterializationStore, MessageReader, Objective,
                        RetryPolicy, RunCoordinator, RunJournal,
                        StoreCorruption, asset, default_catalog)
from repro.core.clients import SimulatedClusterClient


def nofail_factory(faults=None, objective=None):
    return DynamicClientFactory(
        default_catalog(), CostModel(), objective or Objective.balanced(),
        client_builder=lambda p: SimulatedClusterClient(
            p, failure_rate=0.0, preemption_rate=0.0), faults=faults)


def diamond_graph():
    @asset(name="up", compute=ComputeProfile(work_chip_hours=0.01))
    def up(ctx):
        return 21

    @asset(name="mid", deps=("up",),
           compute=ComputeProfile(work_chip_hours=0.01))
    def mid(ctx, up):
        return up + 1

    @asset(name="down", deps=("mid",),
           compute=ComputeProfile(work_chip_hours=0.01))
    def down(ctx, mid):
        return mid * 2

    return AssetGraph([up, mid, down])


TASKS = [("up", "__all__"), ("mid", "__all__"), ("down", "__all__")]


# --------------------------------------------------------------- journal unit
def test_journal_roundtrip_and_idempotent_reopen(tmp_path):
    j = RunJournal(str(tmp_path), "r1")
    j.append("BEGIN", targets=["a"], force=False)
    j.append("LAUNCH", asset="a", partition="p", platform="x", attempt=1)
    j.append("BILL", asset="a", partition="p", platform="x", attempt=1,
             cost_usd=1.5, outcome="success")
    j.close()
    recs, dropped = RunJournal.load(str(tmp_path), "r1")
    assert dropped == 0
    assert [r["kind"] for r in recs] == ["BEGIN", "LAUNCH", "BILL"]
    assert recs[2]["payload"]["cost_usd"] == 1.5
    # reopening continues the seq chain instead of restarting it
    j2 = RunJournal(str(tmp_path), "r1")
    j2.append("RESUME")
    j2.close()
    recs2, _ = RunJournal.load(str(tmp_path), "r1")
    assert [r["seq"] for r in recs2] == [0, 1, 2, 3]


def test_journal_torn_tail_dropped(tmp_path):
    j = RunJournal(str(tmp_path), "r2")
    j.append("BEGIN", targets=["a"])
    j.append("LAUNCH", asset="a", partition="p", platform="x", attempt=1)
    j.close()
    FaultPlan(seed=3).tear_journal(str(tmp_path), "r2", drop_bytes=10)
    with pytest.warns(JournalCorruption):
        recs, dropped = RunJournal.load(str(tmp_path), "r2")
    assert dropped == 1
    assert [r["kind"] for r in recs] == ["BEGIN"]


def test_journal_midfile_corruption_truncates_trust(tmp_path):
    j = RunJournal(str(tmp_path), "r3")
    for i in range(4):
        j.append("LAUNCH" if i else "BEGIN", asset="a", partition="p",
                 platform="x", attempt=i)
    j.close()
    path = RunJournal.path_for(str(tmp_path), "r3")
    lines = open(path).readlines()
    lines[1] = lines[1].replace('"LAUNCH"', '"LUANCH"')
    open(path, "w").writelines(lines)
    with pytest.warns(JournalCorruption):
        recs, dropped = RunJournal.load(str(tmp_path), "r3")
    # conservative: the mangled line and everything after it is untrusted
    assert len(recs) == 1 and dropped == 3


def test_journal_state_frontier_and_billing_keys(tmp_path):
    j = RunJournal(str(tmp_path), "r4")
    j.append("BEGIN", targets=["a", "b"])
    j.append("LAUNCH", asset="a", partition="p", platform="x", attempt=1)
    j.append("BILL", asset="a", partition="p", platform="x", attempt=1,
             cost_usd=1.0, outcome="failure")
    j.append("LAUNCH", asset="a", partition="p", platform="y", attempt=2)
    j.append("LAUNCH", asset="b", partition="p", platform="x", attempt=1)
    j.append("BILL", asset="b", partition="p", platform="x", attempt=1,
             cost_usd=2.0, outcome="success", sim_duration_s=5.0)
    j.close()
    st = JournalState.from_records(RunJournal.load(str(tmp_path), "r4")[0])
    # a[2] is in flight; b success-billed but no SUCCESS landed -> frontier
    assert st.frontier() == {("a", "p"), ("b", "p")}
    assert st.in_flight() == {("a", "p"): st.launches[("a", "p")][1:]}
    assert st.spent_usd() == pytest.approx(3.0)
    assert st.terminal_attempts(("a", "p")) == {1}
    assert len(set(st.billed_keys())) == 2


# ------------------------------------------------------------- store hardening
def test_store_corrupt_index_quarantined(tmp_path):
    d = str(tmp_path / "store")
    MaterializationStore(d).put("a", "p", 1, "fp")
    with open(os.path.join(d, "index.json"), "w") as f:
        f.write('{"version": 2, "records": [{"asset"')
    with pytest.warns(StoreCorruption):
        st = MaterializationStore(d)
    assert len(st) == 0
    assert os.path.exists(os.path.join(d, "index.json.corrupt-0"))
    # the store still works after quarantine
    st.put("a", "p", 2, "fp2")
    assert MaterializationStore(d).get("a", "p") == 2


def test_store_blob_corruption_detected_on_get(tmp_path):
    d = str(tmp_path / "store")
    st = MaterializationStore(d)
    rec = st.put("a", "p", {"v": 1}, "fp")
    FaultPlan(seed=0).corrupt_blob(d, rec["data_hash"])
    with pytest.warns(StoreCorruption):
        with pytest.raises(KeyError, match="integrity"):
            st.get("a", "p")
    # demoted to never-materialized, evidence quarantined
    assert st.record("a", "p") is None
    blobs = os.listdir(os.path.join(d, "blobs"))
    assert any(".corrupt-" in b for b in blobs)


def test_store_blob_truncation_detected_by_verify(tmp_path):
    d = str(tmp_path / "store")
    st = MaterializationStore(d)
    rec = st.put("a", "p", list(range(100)), "fp")
    assert st.verify("a", "p")
    FaultPlan(seed=1).truncate_blob(d, rec["data_hash"])
    with pytest.warns(StoreCorruption):
        assert not st.verify("a", "p")
    assert not st.verify("a", "p")  # record gone; second call is cheap


def test_store_index_survives_partial_write_protocol(tmp_path):
    """index.json is published via tmp+fsync+rename: no .tmp leftovers and
    a reopened store sees every record."""
    d = str(tmp_path / "store")
    st = MaterializationStore(d)
    for i in range(5):
        st.put("a", f"p{i}", i, f"fp{i}")
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]
    assert len(MaterializationStore(d)) == 5


# ------------------------------------------------------------------ chaos sweep
def test_kill_at_every_journal_boundary_then_resume(tmp_path):
    """The tentpole acceptance test.  For every kill point k: the resumed
    run completes, bills exactly once per attempt, spends exactly what an
    uninterrupted run of the same run_id spends, leaves byte-identical
    store contents, and only re-launches tasks from the crash frontier."""
    g = diamond_graph()
    # discover the happy-path record count first
    j0 = str(tmp_path / "j0")
    c0 = RunCoordinator(g, nofail_factory(),
                        store=MaterializationStore(str(tmp_path / "s0")),
                        journal_dir=j0)
    assert c0.materialize(["down"], run_id="probe").ok
    n = RunJournal.load(j0, "probe")[0][-1]["seq"] + 1
    assert n >= 8  # BEGIN + 3x(LAUNCH/BILL/SUCCESS) + END at minimum

    for k in range(1, n + 1):
        rid = f"r{k}"
        # uninterrupted baseline with the SAME run_id (sim durations and
        # costs are keyed on run_id, so this is the exact reference)
        cb = RunCoordinator(
            g, nofail_factory(),
            store=MaterializationStore(str(tmp_path / f"bs{k}")),
            journal_dir=str(tmp_path / f"bj{k}"))
        assert cb.materialize(["down"], run_id=rid).ok
        base_hashes = {tk: cb.store.data_hash(*tk) for tk in TASKS}
        base_spend = JournalState.from_records(
            RunJournal.load(str(tmp_path / f"bj{k}"), rid)[0]).spent_usd()

        # chaos run: killed after journal record k becomes durable
        sdir, jdir = str(tmp_path / f"s{k}"), str(tmp_path / f"j{k}")
        fp = FaultPlan(seed=1, kill_at_record=k)
        c1 = RunCoordinator(g, nofail_factory(faults=fp),
                            store=MaterializationStore(sdir),
                            journal_dir=jdir, faults=fp)
        with pytest.raises(CoordinatorKilled):
            c1.materialize(["down"], run_id=rid)

        pre = JournalState.from_records(RunJournal.load(jdir, rid)[0])
        frontier = pre.frontier()
        launched_before = set(pre.launches)

        c2 = RunCoordinator(g, nofail_factory(),
                            store=MaterializationStore(sdir),
                            journal_dir=jdir)
        if pre.ended and pre.ok:
            # killed after END: the run was already complete
            with pytest.raises(ValueError, match="already ended ok"):
                c2.resume(rid)
        else:
            assert c2.resume(rid).ok, f"kill point {k}"

        post_recs, _ = RunJournal.load(jdir, rid)
        post = JournalState.from_records(post_recs)
        # 1. byte-identical store contents vs the uninterrupted run
        got = {tk: c2.store.data_hash(*tk) for tk in TASKS}
        assert got == base_hashes, f"kill point {k}: store diverged"
        # 2. exactly-once billing: no duplicate idempotency keys, and the
        #    total spend matches the uninterrupted run to the cent
        keys = post.billed_keys()
        assert len(keys) == len(set(keys)), f"kill point {k}: double billed"
        assert post.spent_usd() == pytest.approx(base_spend, abs=1e-9), \
            f"kill point {k}: spend diverged"
        # 3. rework bounded by the frontier: every task the resumed run
        #    re-launched had either been in flight / success-billed-unlanded
        #    at the crash, or had never been launched at all
        resume_seq = next((r["seq"] for r in post_recs
                           if r["kind"] == "RESUME"), None)
        if resume_seq is not None:
            relaunched = {(r["asset"], r["partition"]) for r in post_recs
                          if r["kind"] == "LAUNCH"
                          and r["seq"] > resume_seq}
            rework = relaunched & launched_before
            assert rework <= frontier, \
                f"kill point {k}: rework {rework} exceeds frontier {frontier}"


def test_resume_noop_without_journal_dir(tmp_path):
    c = RunCoordinator(diamond_graph(), nofail_factory())
    with pytest.raises(ValueError, match="journal_dir"):
        c.resume("whatever")


def test_resume_refuses_hard_failed_run(tmp_path):
    """A journaled FAIL (retry budget exhausted) is durable: resume raises
    instead of silently retrying past the policy."""
    always_fail = ClientFaults(failure_rate=1.0)

    @asset(name="doomed", compute=ComputeProfile(work_chip_hours=0.01),
           retry=RetryPolicy(max_attempts=2, backoff_s=0.0))
    def doomed(ctx):
        return 1

    g = AssetGraph([doomed])
    fp = FaultPlan(seed=0, client=always_fail)
    fac = DynamicClientFactory(default_catalog(), CostModel(),
                               Objective.balanced(), sim_seed=0, faults=fp)
    jdir = str(tmp_path / "j")
    c = RunCoordinator(g, fac, store=MaterializationStore(str(tmp_path / "s")),
                       journal_dir=jdir)
    with pytest.raises(RuntimeError, match="failed after"):
        c.materialize(["doomed"], run_id="dead")
    st = JournalState.from_records(RunJournal.load(jdir, "dead")[0])
    assert ("doomed", "__all__") in st.failed and st.ended and not st.ok
    c2 = RunCoordinator(g, nofail_factory(),
                        store=MaterializationStore(str(tmp_path / "s")),
                        journal_dir=jdir)
    with pytest.raises(RuntimeError, match="hard-failed"):
        c2.resume("dead")


def test_resume_after_torn_journal_tail(tmp_path):
    g = diamond_graph()
    sdir, jdir = str(tmp_path / "s"), str(tmp_path / "j")
    fp = FaultPlan(seed=3, kill_at_record=5)
    c = RunCoordinator(g, nofail_factory(faults=fp),
                       store=MaterializationStore(sdir), journal_dir=jdir,
                       faults=fp)
    with pytest.raises(CoordinatorKilled):
        c.materialize(["down"], run_id="torn")
    FaultPlan(seed=7).tear_journal(jdir, "torn")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        c2 = RunCoordinator(g, nofail_factory(),
                            store=MaterializationStore(sdir),
                            journal_dir=jdir)
        rep = c2.resume("torn")
    assert rep.ok
    assert c2.store.get("down", "__all__") == 44


def test_resume_requarantines_corrupt_blob(tmp_path):
    """Integrity sweep on resume: a blob corrupted while the coordinator was
    dead is quarantined, its task re-runs, downstream stays consistent."""
    g = diamond_graph()
    sdir, jdir = str(tmp_path / "s"), str(tmp_path / "j")
    # kill right before END: everything landed, run not closed
    c0 = RunCoordinator(g, nofail_factory(),
                        store=MaterializationStore(str(tmp_path / "bs")),
                        journal_dir=str(tmp_path / "bj"))
    assert c0.materialize(["down"], run_id="corr").ok
    n = RunJournal.load(str(tmp_path / "bj"), "corr")[0][-1]["seq"] + 1
    fp = FaultPlan(seed=0, kill_at_record=n - 1)
    c = RunCoordinator(g, nofail_factory(faults=fp),
                       store=MaterializationStore(sdir), journal_dir=jdir,
                       faults=fp)
    with pytest.raises(CoordinatorKilled):
        c.materialize(["down"], run_id="corr")
    dh = MaterializationStore(sdir).data_hash("up", "__all__")
    FaultPlan(seed=5).corrupt_blob(sdir, dh)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        c2 = RunCoordinator(g, nofail_factory(),
                            store=MaterializationStore(sdir),
                            journal_dir=jdir)
        rep = c2.resume("corr")
    assert rep.ok
    assert c2.store.get("up", "__all__") == 21
    assert c2.store.get("down", "__all__") == 44
    # no double billing even through the corruption re-run
    st = JournalState.from_records(RunJournal.load(jdir, "corr")[0])
    keys = st.billed_keys()
    assert len(keys) == len(set(keys))


def test_adaptive_state_carries_across_resume(tmp_path):
    """BILL records double as training data: a resumed coordinator's online
    model starts with the crashed run's observations, not catalog priors."""
    g = diamond_graph()
    sdir, jdir = str(tmp_path / "s"), str(tmp_path / "j")
    fp = FaultPlan(seed=0, kill_at_record=6)
    c = RunCoordinator(g, nofail_factory(faults=fp),
                       store=MaterializationStore(sdir), journal_dir=jdir,
                       faults=fp, adaptive=True)
    with pytest.raises(CoordinatorKilled):
        c.materialize(["down"], run_id="ad")
    pre_bills = JournalState.from_records(
        RunJournal.load(jdir, "ad")[0]).bills
    assert pre_bills  # the crash left something to learn from
    c2 = RunCoordinator(g, nofail_factory(),
                        store=MaterializationStore(sdir), journal_dir=jdir,
                        adaptive=True)
    rep = c2.resume("ad")
    assert rep.ok
    # every pre-crash billed (asset, platform) cell has observations
    for b in pre_bills:
        assert c2.adaptive.model.observations(b["asset"], b["platform"]) > 0


def test_client_fault_overrides_degrade_platform(tmp_path):
    """A FaultPlan client override makes reality diverge from the catalog on
    one platform; the run still completes through retries/failover and the
    failed attempts are billed (Fig-3 economics under injected faults)."""
    @asset(name="bulk", compute=ComputeProfile(work_chip_hours=0.05),
           retry=RetryPolicy(max_attempts=6, backoff_s=0.0,
                             failover_after=2),
           platform_hint="pod-spot")
    def bulk(ctx):
        return 7

    g = AssetGraph([bulk])
    fp = FaultPlan(seed=0, client=ClientFaults(platforms=("pod-spot",),
                                               failure_rate=1.0))
    fac = DynamicClientFactory(default_catalog(), CostModel(),
                               Objective.balanced(), sim_seed=0, faults=fp)
    c = RunCoordinator(g, fac, store=MaterializationStore(str(tmp_path / "s")))
    rep = c.materialize(["bulk"], run_id="cf")
    assert rep.ok
    rec = rep.records[0]
    plats = [a.platform for a in rec.attempts]
    assert "pod-spot" in plats  # it tried the sick platform
    assert rec.attempts[-1].platform != "pod-spot"  # and failed over
    assert sum(a.cost_usd for a in rec.attempts
               if a.status != "success") > 0  # failed attempts still bill


# -------------------------------------------------- telemetry ring regression
def test_events_since_correct_across_compaction():
    """``events_since`` (the adaptive controller's cursor) must never return
    duplicate or out-of-order seqs across ring compaction, and
    ``min_live_seq`` must flag exactly the evicted prefix."""
    r = MessageReader(max_events=8)
    seen: list[int] = []
    cursor = 0
    for i in range(50):
        r.emit("run", f"a{i}", "p", "x", "COST", total_usd=1.0,
               outcome="success")
        if i % 7 == 0:  # poll irregularly, straddling compactions
            for e in r.events_since(cursor):
                seen.append(e.seq)
                cursor = e.seq + 1
    for e in r.events_since(cursor):
        seen.append(e.seq)
    assert seen == sorted(set(seen))  # no dupes, strictly increasing
    assert r.evicted_events > 0  # compaction actually happened
    assert r.min_live_seq > 0
    # lifetime aggregates survived eviction
    assert r.total_cost() == pytest.approx(50.0)


def test_events_since_during_resumed_run_with_tiny_ring(tmp_path):
    """A resumed adaptive run whose reader compacts aggressively still
    completes and still learns — the seq cursor survives eviction (missed
    events are gone, but never duplicated or misordered)."""
    g = diamond_graph()
    sdir, jdir = str(tmp_path / "s"), str(tmp_path / "j")
    fp = FaultPlan(seed=0, kill_at_record=6)
    c = RunCoordinator(g, nofail_factory(faults=fp),
                       store=MaterializationStore(sdir), journal_dir=jdir,
                       faults=fp, adaptive=True, reader=MessageReader(max_events=4))
    with pytest.raises(CoordinatorKilled):
        c.materialize(["down"], run_id="ring")
    c2 = RunCoordinator(g, nofail_factory(),
                        store=MaterializationStore(sdir), journal_dir=jdir,
                        adaptive=True, reader=MessageReader(max_events=4))
    rep = c2.resume("ring")
    assert rep.ok
    assert c2.reader.evicted_events > 0
    # cursor never ran past the ring: controller consumed to the live head
    assert c2.adaptive._cursor >= c2.reader.min_live_seq


# ------------------------------------------------------------------ cli preview
def test_resume_preview_cli(tmp_path, capsys):
    g = diamond_graph()
    sdir, jdir = str(tmp_path / "s"), str(tmp_path / "j")
    fp = FaultPlan(seed=1, kill_at_record=5)
    c = RunCoordinator(g, nofail_factory(faults=fp),
                       store=MaterializationStore(sdir), journal_dir=jdir,
                       faults=fp)
    with pytest.raises(CoordinatorKilled):
        c.materialize(["down"], run_id="prev")
    from repro.launch.dryrun import resume_preview
    resume_preview(jdir, "prev")
    out = capsys.readouterr().out
    assert "run prev" in out and "resume would re-launch" in out
    with pytest.raises(SystemExit, match="no journal"):
        resume_preview(jdir, "nope")
