"""Autotuner contract tests: candidate validation (divisibility, VMEM fit),
cache-hit-does-zero-timing, persistent round-trip (tune -> persist ->
reload -> identical plan with no re-timing), heuristic fallbacks staying
in-process, and the ops-layer dispatch rules (explicit kwargs bypass the
tuner; tuned=True on a non-TPU host resolves without timing)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as at
from repro.kernels import ops
from repro.kernels.ref import attention_ref


@pytest.fixture
def isolated_tuner(tmp_path, monkeypatch):
    """Fresh process-global tuner wired to an empty tmp cache (the committed
    baseline store must not leak into these tests)."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "cache.json"))
    monkeypatch.setattr(at, "BASELINE_CACHE_PATH",
                        str(tmp_path / "no_baseline.json"))
    at.reset_tuner()
    yield at.get_tuner()
    at.reset_tuner()


# ------------------------------------------------------------- candidates
def test_attention_candidates_divide_and_fit():
    cands = at.attention_candidates(512, 512, 64, 64, jnp.float32)
    assert cands, "ladder must produce candidates for a 512-seq f32 case"
    for c in cands:
        assert 512 % c.block_q == 0 and 512 % c.block_k == 0
        assert at.attention_vmem_bytes(c.block_q, c.block_k, 64, 64,
                                       jnp.float32) <= at.VMEM_BUDGET
    # the fixed defaults are reachable, so tuned >= default by construction
    assert {"block_q": 128, "block_k": 128} in [c.as_dict() for c in cands]


def test_attention_candidates_clamp_to_short_sequences():
    cands = at.attention_candidates(64, 64, 64, 64, jnp.float32)
    assert all(c.block_q <= 64 and c.block_k <= 64 for c in cands)
    # ladder values above S clamp onto S and dedupe to one entry
    assert len({(c.block_q, c.block_k) for c in cands}) == len(cands)


def test_attention_candidates_vmem_budget_excludes_big_tiles():
    tight = at.attention_vmem_bytes(128, 128, 64, 64, jnp.float32) + 1
    cands = at.attention_candidates(512, 512, 64, 64, jnp.float32,
                                    vmem_budget=tight)
    assert cands
    assert all(at.attention_vmem_bytes(c.block_q, c.block_k, 64, 64,
                                       jnp.float32) <= tight for c in cands)
    assert not any(c.block_q == 512 and c.block_k == 512 for c in cands)


def test_scan_candidates_divide_and_fit():
    cands = at.scan_candidates(512, 64, jnp.float32)
    assert cands
    for c in cands:
        assert 512 % c.chunk == 0
        assert at.scan_vmem_bytes(c.chunk, 64, jnp.float32) <= at.VMEM_BUDGET


def test_heuristics_return_valid_tiles():
    cfg = at.heuristic_attention(512, 512, 64, 64, jnp.bfloat16)
    assert 512 % cfg["block_q"] == 0 and 512 % cfg["block_k"] == 0
    wide = at.heuristic_attention(512, 512, 256, 256, jnp.bfloat16)
    assert wide["block_q"] <= cfg["block_q"]  # wide heads take narrower tiles
    scfg = at.heuristic_scan(512, 64, jnp.float32)
    assert 512 % scfg["chunk"] == 0


# ---------------------------------------------------------------- caching
def _fake_measure(log):
    def measure(cfg):
        log.append(dict(cfg))
        # deterministic synthetic cost: prefer the largest block_q/chunk
        return 1000.0 / float(sum(cfg.values()))
    return measure


def test_tune_picks_best_and_hit_does_zero_timing(isolated_tuner):
    tuner = isolated_tuner
    cands = at.attention_candidates(256, 256, 64, 64, jnp.float32)
    log = []
    entry = tuner.tune("k1", cands, _fake_measure(log), mode="test")
    assert len(log) == len(cands) == tuner.timing_calls
    best = max(cands, key=lambda c: c.block_q + c.block_k)
    assert entry["config"] == best.as_dict()
    # hit: identical entry back, measure never called, no timing work
    log2 = []
    again = tuner.tune("k1", cands, _fake_measure(log2), mode="test")
    assert again == entry
    assert log2 == [] and tuner.timing_calls == len(cands)


def test_cache_round_trip_reload_without_retiming(isolated_tuner, tmp_path):
    tuner = isolated_tuner
    cands = at.scan_candidates(256, 64, jnp.float32)
    entry = tuner.tune("scan-key", cands, _fake_measure([]), mode="test")
    assert os.path.exists(tuner.cache_path)

    def explode(cfg):  # a reload must never time anything
        raise AssertionError("re-timing after reload")

    fresh = at.Autotuner(cache_path=tuner.cache_path,
                         baseline_path=str(tmp_path / "none.json"))
    assert fresh.tune("scan-key", cands, explode, mode="test") == entry
    assert fresh.resolve("scan-key", explode) == entry["config"]
    assert fresh.timing_calls == 0


def test_baseline_merges_and_local_wins(tmp_path):
    base, local = tmp_path / "base.json", tmp_path / "local.json"
    base.write_text(json.dumps({"version": 1, "entries": {
        "shared": {"config": {"chunk": 16}, "mode": "tpu"},
        "base-only": {"config": {"chunk": 32}, "mode": "tpu"}}}))
    local.write_text(json.dumps({"version": 1, "entries": {
        "shared": {"config": {"chunk": 64}, "mode": "tpu"}}}))
    tuner = at.Autotuner(cache_path=str(local), baseline_path=str(base))
    assert tuner.lookup("shared")["config"] == {"chunk": 64}
    assert tuner.lookup("base-only")["config"] == {"chunk": 32}


def test_heuristic_entries_stay_in_process(isolated_tuner):
    tuner = isolated_tuner
    cfg = tuner.resolve("miss-key", lambda: {"chunk": 64})
    assert cfg == {"chunk": 64}
    assert tuner.timing_calls == 0
    assert not os.path.exists(tuner.cache_path)  # nothing persisted
    # a later real tune overrides the heuristic placeholder
    cands = at.scan_candidates(128, 64, jnp.float32)
    entry = tuner.tune("miss-key", cands, _fake_measure([]), mode="test")
    assert entry["mode"] == "test"
    assert tuner.resolve("miss-key", lambda: {"chunk": 1}) == entry["config"]


def test_force_retune_overrides_cached_entry(isolated_tuner):
    tuner = isolated_tuner
    cands = at.scan_candidates(256, 64, jnp.float32)
    tuner.tune("k", cands, _fake_measure([]), mode="test")
    log = []
    tuner.tune("k", cands, _fake_measure(log), mode="test", force=True)
    assert len(log) == len(cands)  # re-timed despite the hit


def test_persist_merges_concurrent_writers(tmp_path):
    path = str(tmp_path / "cache.json")
    a = at.Autotuner(cache_path=path, baseline_path="")
    b = at.Autotuner(cache_path=path, baseline_path="")
    a.put("ka", {"config": {"chunk": 16}, "mode": "tpu"})
    b.put("kb", {"config": {"chunk": 32}, "mode": "tpu"})
    merged = at.Autotuner(cache_path=path, baseline_path="")
    assert merged.lookup("ka")["config"] == {"chunk": 16}
    assert merged.lookup("kb")["config"] == {"chunk": 32}


def test_cache_keys_distinguish_backend_and_flags():
    shape = (1, 256, 2, 64)
    k1 = at.attention_key(shape, shape, shape, jnp.float32, causal=True,
                          window=0, backend="cpu")
    k2 = at.attention_key(shape, shape, shape, jnp.float32, causal=False,
                          window=0, backend="cpu")
    k3 = at.attention_key(shape, shape, shape, jnp.float32, causal=True,
                          window=0, backend="cpu+interp")
    k4 = at.attention_key(shape, shape, shape, jnp.bfloat16, causal=True,
                          window=0, backend="cpu")
    assert len({k1, k2, k3, k4}) == 4


# ------------------------------------------------------------ ops dispatch
def _attn_inputs(S=128, D=64, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, S, 2, D), dtype)
    k = jax.random.normal(ks[1], (1, S, 2, D), dtype)
    v = jax.random.normal(ks[2], (1, S, 2, D), dtype)
    return q, k, v


def test_explicit_kwargs_bypass_tuner(monkeypatch):
    """block_q=/block_k= (and chunk=) pin the tiles: the tuner must not even
    be constructed, tuned or not."""
    def explode():
        raise AssertionError("tuner consulted despite explicit kwargs")
    monkeypatch.setattr(at, "get_tuner", explode)
    q, k, v = _attn_inputs()
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              tuned=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_tuned_dispatch_on_cpu_is_heuristic_with_zero_timing(isolated_tuner):
    """tuned=True on a non-TPU host: resolves via the heuristic (no timing
    search at dispatch), matches the reference, and the resolved entry is
    not persisted."""
    tuner = isolated_tuner
    q, k, v = _attn_inputs()
    out = ops.flash_attention(q, k, v, causal=True, tuned=True,
                              interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert tuner.timing_calls == 0
    assert not os.path.exists(tuner.cache_path)


def test_tuned_dispatch_prefers_cached_entry(isolated_tuner):
    """A cached (baseline-shipped) entry wins over the heuristic at
    dispatch, with zero timing work."""
    tuner = isolated_tuner
    q, k, v = _attn_inputs()
    key = at.attention_key(q.shape, k.shape, v.shape, q.dtype, causal=True,
                           window=0, backend=at.backend_tag(True))
    tuner.put(key, {"config": {"block_q": 32, "block_k": 32},
                    "mode": "interpret"}, persist=False)
    out = ops.flash_attention(q, k, v, causal=True, tuned=True,
                              interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert tuner.timing_calls == 0


def test_tuned_dispatch_inside_jit_trace_uses_heuristic(isolated_tuner):
    """tuned=True reached under a jax trace (tracer inputs) must not try to
    time anything — it falls back to the heuristic and stays correct."""
    tuner = isolated_tuner
    q, k, v = _attn_inputs()

    @jax.jit
    def wrapped(q, k, v):
        return ops.flash_attention(q, k, v, causal=True, tuned=True,
                                   interpret=True)

    out = wrapped(q, k, v)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert tuner.timing_calls == 0


def test_committed_baseline_cache_is_well_formed():
    """The baseline shipped in-repo must parse and carry only timed entries
    with valid tile configs."""
    path = at.BASELINE_CACHE_PATH
    if not os.path.exists(path):
        pytest.skip("no committed autotune baseline")
    with open(path) as f:
        data = json.load(f)
    assert data.get("entries"), "baseline cache is empty"
    for key, entry in data["entries"].items():
        assert entry["mode"] != "heuristic"
        cfg = entry["config"]
        if key.startswith("flash_attention|"):
            assert set(cfg) == {"block_q", "block_k"}
        else:
            assert set(cfg) == {"chunk"}
        assert all(isinstance(x, int) and x > 0 for x in cfg.values())
