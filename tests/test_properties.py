"""Hypothesis property tests on system invariants (assignment requirement).

The whole module is skipped (not a collection error) when the ``hypothesis``
dev extra is not installed, so the tier-1 suite stays runnable from a
runtime-only install."""
import string

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra not installed")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (AssetGraph, ComputeProfile, CostModel,
                        MultiPartitions, StaticPartitions,
                        TimeWindowPartitions, asset, default_catalog)
from repro.core.costmodel import roofline_seconds
from repro.data import TokenDataset

names = st.lists(st.text(alphabet=string.ascii_lowercase, min_size=1,
                         max_size=6), min_size=1, max_size=12, unique=True)


@given(names, st.data())
@settings(max_examples=40, deadline=None)
def test_topo_order_respects_deps(ns, data):
    """For random DAGs (edges only from earlier to later names), topo order
    places every dep before its consumer."""
    specs = []
    for i, n in enumerate(ns):
        possible = ns[:i]
        deps = tuple(data.draw(st.lists(st.sampled_from(possible),
                                        max_size=min(3, len(possible)),
                                        unique=True))) if possible else ()
        specs.append(asset(name=n, deps=deps)(lambda ctx, **kw: None))
    g = AssetGraph(specs)
    order = g.topo_order()
    pos = {n: i for i, n in enumerate(order)}
    for s in specs:
        for d in s.deps:
            assert pos[d] < pos[s.name]


@given(st.floats(0.01, 1e4), st.integers(1, 4096), st.floats(1.0, 3.0))
@settings(max_examples=50, deadline=None)
def test_roofline_seconds_monotone_in_chips(work, chips, factor):
    c = ComputeProfile(work_chip_hours=work)
    t1 = roofline_seconds(c, chips)
    t2 = roofline_seconds(c, int(chips * factor) + 1)
    assert t2 <= t1 + 1e-9


@given(st.floats(0.1, 1e4))
@settings(max_examples=30, deadline=None)
def test_cost_estimate_decomposition(work):
    """total == base + surcharge + storage, surcharge == rate * base."""
    cm = CostModel()
    spec = asset(name="a", compute=ComputeProfile(work_chip_hours=work))(
        lambda ctx: None)
    for p in default_catalog().values():
        est = cm.estimate(spec, p)
        assert abs(est.total_usd - (est.base_usd + est.surcharge_usd
                                    + est.storage_usd)) < 1e-6
        assert abs(est.surcharge_usd - est.base_usd * p.surcharge_rate) < 1e-6
        assert est.duration_s >= est.compute_s


@given(st.integers(2020, 2030), st.integers(1, 12), st.integers(0, 30))
@settings(max_examples=30, deadline=None)
def test_time_partitions_contiguous(y, m, span):
    y1, m1 = y + (m - 1 + span) // 12, (m - 1 + span) % 12 + 1
    p = TimeWindowPartitions(f"{y:04d}-{m:02d}", f"{y1:04d}-{m1:02d}")
    keys = p.keys()
    assert len(keys) == span + 1
    assert len(set(keys)) == len(keys)


@given(st.lists(st.sampled_from(["a", "b", "c", "d"]), min_size=1,
                max_size=3, unique=True),
       st.lists(st.sampled_from(["x", "y", "z"]), min_size=1, max_size=3,
                unique=True))
@settings(max_examples=20, deadline=None)
def test_multi_partition_split_roundtrip(t, d):
    p = MultiPartitions(dims=(("time", StaticPartitions(tuple(t))),
                              ("domain", StaticPartitions(tuple(d)))))
    for key in p.keys():
        dims = p.split(key)
        assert "/".join(dims.values()) == key
    assert len(p.keys()) == len(t) * len(d)


@given(st.integers(0, 1000), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_dataset_deterministic_and_distinct(s1, s2):
    ds = TokenDataset(vocab_size=128, seq_len=16, global_batch=2,
                      partition="2024-01/p")
    b1 = ds.batch(s1)
    b1_again = ds.batch(s1)
    np.testing.assert_array_equal(b1["tokens"], b1_again["tokens"])
    # next-token alignment: targets are tokens shifted by one
    seq_full = np.concatenate([b1["tokens"][:, :1],
                               b1["targets"]], axis=1)
    np.testing.assert_array_equal(seq_full[:, :-1], b1["tokens"])
    if s1 != s2:
        b2 = ds.batch(s2)
        assert not np.array_equal(b1["tokens"], b2["tokens"])


@given(st.sampled_from(["2023-10/s0", "2023-11/s0", "2023-10/s1"]))
@settings(max_examples=10, deadline=None)
def test_dataset_partitions_disjoint_streams(part):
    a = TokenDataset(vocab_size=64, seq_len=8, global_batch=1,
                     partition=part).batch(0)
    b = TokenDataset(vocab_size=64, seq_len=8, global_batch=1,
                     partition=part + "x").batch(0)
    assert not np.array_equal(a["tokens"], b["tokens"])
