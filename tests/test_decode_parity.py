"""Decode-path correctness: prefill + incremental decode must reproduce the
full-forward logits (the KV caches / ring buffers / recurrent states and the
MLA absorbed-decode path are all exercised by this parity check)."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import LanguageModel

ARCHS = [
    "whisper-medium", "h2o-danube-1.8b", "gemma-2b", "minicpm3-4b",
    "deepseek-7b", "recurrentgemma-9b", "deepseek-v2-236b",
    "granite-moe-1b-a400m", "qwen2-vl-72b", "rwkv6-1.6b",
]


def smoke_config(arch: str):
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    # f32 compute for a tight parity bound
    return mod.smoke().scaled(compute_dtype="float32")


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = smoke_config(arch)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(rng.randn(B, S, cfg.d_model), jnp.float32)

    # ---- reference: full forward logits at every position ----------------
    def full_logits(p):
        b = dict(batch, targets=tokens, weights=jnp.ones((B, S), jnp.float32))
        pos = model._positions(B, S, None)
        from repro.models.attention import ModelCtx
        ctx = ModelCtx(mode="train", positions=pos)
        if cfg.enc_dec:
            enc_out, enc_pos = model._encode(p, b["frames"])
            ctx = ModelCtx(mode="train", positions=pos, enc_out=enc_out,
                           enc_positions=enc_pos)
        x = model._embed(p, tokens)
        if cfg.pos_type == "learned":
            x = x + jnp.take(p["pos_embed"], pos, axis=0).astype(x.dtype)
        x, _, _ = model._backbone(p, x, None, ctx)
        return model._head(p, x)

    ref = np.asarray(jax.jit(full_logits)(params))  # (B, S, V)

    # ---- prefill on the first half, decode the rest token by token -------
    S0 = S // 2
    cache = model.init_cache(B, max_len=S, enc_len=S, dtype=jnp.float32)
    pre_batch = {k: (v[:, :S0] if k == "tokens" else v) for k, v in batch.items()}
    logits, cache = jax.jit(model.prefill)(params, pre_batch, cache)
    np.testing.assert_allclose(np.asarray(logits), ref[:, S0 - 1], rtol=2e-4,
                               atol=2e-4)

    step = jax.jit(model.decode_step)
    for t in range(S0, S):
        tok = tokens[:, t][:, None]
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = step(params, tok, cache, pos)
        np.testing.assert_allclose(
            np.asarray(logits), ref[:, t], rtol=3e-4, atol=3e-4,
            err_msg=f"{arch}: decode step {t} diverged from full forward")
