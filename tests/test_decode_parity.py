"""Decode-path correctness: prefill + incremental decode must reproduce the
full-forward logits (the KV caches / ring buffers / recurrent states and the
MLA absorbed-decode path are all exercised by this parity check)."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import LanguageModel

ARCHS = [
    "whisper-medium", "h2o-danube-1.8b", "gemma-2b", "minicpm3-4b",
    "deepseek-7b", "recurrentgemma-9b", "deepseek-v2-236b",
    "granite-moe-1b-a400m", "qwen2-vl-72b", "rwkv6-1.6b",
]


def smoke_config(arch: str):
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    # f32 compute for a tight parity bound
    return mod.smoke().scaled(compute_dtype="float32")


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = smoke_config(arch)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(rng.randn(B, S, cfg.d_model), jnp.float32)

    # ---- reference: full forward logits at every position ----------------
    def full_logits(p):
        b = dict(batch, targets=tokens, weights=jnp.ones((B, S), jnp.float32))
        pos = model._positions(B, S, None)
        from repro.models.attention import ModelCtx
        ctx = ModelCtx(mode="train", positions=pos)
        if cfg.enc_dec:
            enc_out, enc_pos = model._encode(p, b["frames"])
            ctx = ModelCtx(mode="train", positions=pos, enc_out=enc_out,
                           enc_positions=enc_pos)
        x = model._embed(p, tokens)
        if cfg.pos_type == "learned":
            x = x + jnp.take(p["pos_embed"], pos, axis=0).astype(x.dtype)
        x, _, _ = model._backbone(p, x, None, ctx)
        return model._head(p, x)

    ref = np.asarray(jax.jit(full_logits)(params))  # (B, S, V)

    # ---- prefill on the first half, decode the rest token by token -------
    S0 = S // 2
    cache = model.init_cache(B, max_len=S, enc_len=S, dtype=jnp.float32)
    pre_batch = {k: (v[:, :S0] if k == "tokens" else v) for k, v in batch.items()}
    logits, cache = jax.jit(model.prefill)(params, pre_batch, cache)
    np.testing.assert_allclose(np.asarray(logits), ref[:, S0 - 1], rtol=2e-4,
                               atol=2e-4)

    step = jax.jit(model.decode_step)
    for t in range(S0, S):
        tok = tokens[:, t][:, None]
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = step(params, tok, cache, pos)
        np.testing.assert_allclose(
            np.asarray(logits), ref[:, t], rtol=3e-4, atol=3e-4,
            err_msg=f"{arch}: decode step {t} diverged from full forward")


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_chunked_decode_matches_full_forward(arch):
    """Chunked prefill through PagedKVCache slot views + paged decode with a
    block table must reproduce the full-forward logits, same bound as the
    dense path.  Slot 1 of a 2-slot pool is used (with slot 0 pre-allocated)
    so the table actually indirects: logical pages != physical pages."""
    from repro.launch.paged_kv import PagedKVCache, decompose

    cfg = smoke_config(arch)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 24
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, S)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(rng.randn(1, S, cfg.d_model),
                                      jnp.float32)

    def full_logits(p):
        from repro.models.attention import ModelCtx
        pos = model._positions(1, S, None)
        ctx = ModelCtx(mode="train", positions=pos)
        if cfg.enc_dec:
            enc_out, enc_pos = model._encode(p, batch["frames"])
            ctx = ModelCtx(mode="train", positions=pos, enc_out=enc_out,
                           enc_positions=enc_pos)
        x = model._embed(p, tokens)
        if cfg.pos_type == "learned":
            x = x + jnp.take(p["pos_embed"], pos, axis=0).astype(x.dtype)
        x, _, _ = model._backbone(p, x, None, ctx)
        return model._head(p, x)

    ref = np.asarray(jax.jit(full_logits)(params))  # (1, S, V)

    # pool: 2 slots x 4 pages of 8 tokens; slot 0 pre-allocated so slot 1's
    # physical pages are offset from its logical ones
    kv = PagedKVCache(model, n_slots=2, n_pages=8, page_size=8, max_pages=4,
                      enc_len=S if cfg.enc_dec else 0, dtype=jnp.float32)
    assert kv.alloc(0, 10) and kv.alloc(1, S + 2)

    # ---- chunked prefill of the first half through the slot-1 view -------
    S0 = S // 2
    start = 0
    logits = None
    for c in decompose(S0, 8):
        view = kv.gather_slot(1)
        chunk_batch = {"tokens": tokens[:, start:start + c]}
        if cfg.enc_dec:
            chunk_batch["frames"] = batch["frames"]
        logits, view = model.prefill_chunk(
            params, chunk_batch, view, jnp.full((1,), start, jnp.int32))
        kv.scatter_slot(1, view)
        start += c
    np.testing.assert_allclose(np.asarray(logits), ref[:, S0 - 1], rtol=2e-4,
                               atol=2e-4,
                               err_msg=f"{arch}: chunked prefill diverged")

    # ---- paged decode of the rest against the block table ----------------
    step = jax.jit(model.decode_step)
    for t in range(S0, S):
        view = kv.gather_slot(1)
        # decode through the pool directly: B = n_slots, slot 1 active
        toks = jnp.zeros((2, 1), jnp.int32).at[1, 0].set(tokens[0, t])
        pos = jnp.asarray([-1, t], jnp.int32)  # slot 0 inactive
        logits, kv.cache = step(params, toks, kv.cache, pos, table=kv.table)
        np.testing.assert_allclose(
            np.asarray(logits[1:]), ref[:, t], rtol=3e-4, atol=3e-4,
            err_msg=f"{arch}: paged decode step {t} diverged")
