"""Pallas kernel validation: shape/dtype sweeps, interpret-mode kernel vs the
pure-jnp oracle (assignment requirement: per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.kernel_bench import ATTN_CONFIGS, WKV_CONFIGS
from repro.kernels import ops
from repro.kernels.ref import attention_ref, wkv_ref
from repro.models.recurrent import wkv_chunked


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


ATTN_CASES = [
    # (B, Sq, Skv, Hq, Hkv, D, causal, window, dtype)
    (1, 128, 128, 2, 2, 64, True, 0, jnp.float32),
    (2, 256, 256, 4, 1, 64, True, 0, jnp.float32),   # MQA
    (2, 256, 256, 8, 2, 32, True, 0, jnp.float32),   # GQA 4:1
    (1, 128, 384, 2, 2, 64, True, 0, jnp.float32),   # q_offset continuation
    (1, 256, 256, 2, 2, 64, True, 128, jnp.float32),  # sliding window
    (1, 256, 256, 2, 1, 64, True, 64, jnp.float32),   # narrow window + MQA
    (1, 128, 128, 2, 2, 64, False, 0, jnp.float32),   # bidirectional (encoder)
    (2, 256, 256, 4, 4, 128, True, 0, jnp.bfloat16),
    (1, 384, 384, 2, 2, 256, True, 0, jnp.bfloat16),  # gemma head_dim
    (1, 256, 256, 4, 2, 80, True, 128, jnp.bfloat16),  # danube head_dim + SWA
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_vs_ref(case):
    B, Sq, Skv, Hq, Hkv, D, causal, window, dtype = case
    q_offset = Skv - Sq
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("block", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shapes(block):
    bq, bk = block
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    from repro.kernels.flash_attention import flash_attention
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


WKV_CASES = [
    # (B, S, H, N, chunk)
    (1, 64, 2, 16, 16),
    (2, 128, 2, 32, 32),
    (1, 128, 4, 64, 64),
    (2, 96, 2, 16, 32),  # chunk > remainder handling (96 % 32 == 0)
]


def _wkv_inputs(B, S, H, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (B, S, H, N), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, N), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, N), jnp.float32)
    # realistic decays: log_w = -exp(w_raw), w_raw in [-6, 0]
    w_raw = jax.random.uniform(ks[3], (B, S, H, N), jnp.float32, -6.0, 0.0)
    log_w = -jnp.exp(w_raw)
    u = jax.random.normal(ks[4], (H, N), jnp.float32) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, N, N), jnp.float32) * 0.5
    return r, k, v, log_w, u, s0


@pytest.mark.parametrize("case", WKV_CASES)
def test_linear_scan_kernel_vs_ref(case):
    B, S, H, N, chunk = case
    r, k, v, log_w, u, s0 = _wkv_inputs(B, S, H, N)
    y, s_fin = ops.linear_scan(r, k, v, log_w, u, s0, chunk=chunk,
                               interpret=True)
    y_ref, s_ref = wkv_ref(r, k, v, log_w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_wkv_chunked_xla_path_vs_ref():
    """The XLA chunked-parallel path used in model code must match the oracle."""
    r, k, v, log_w, u, s0 = _wkv_inputs(2, 160, 2, 32, seed=3)
    y, s_fin = wkv_chunked(r, k, v, log_w, u, s0, chunk=32)
    y_ref, s_ref = wkv_ref(r, k, v, log_w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


PAD_ATTN_CASES = [
    # (B, Sq, Skv, Hq, Hkv, D, causal, window) — none divide the 64-tile
    (1, 100, 100, 2, 2, 64, True, 0),
    (1, 100, 100, 2, 2, 64, False, 0),   # bidirectional: kv_len mask is live
    (1, 72, 200, 2, 1, 64, True, 48),    # window + MQA + uneven q/k pads
]


@pytest.mark.parametrize("case", PAD_ATTN_CASES)
def test_flash_attention_padded_shapes_vs_ref(case):
    """Arbitrary (non-block-multiple) sequence lengths run through the
    pad-to-block / slice-back wrapper and must still match the oracle."""
    B, Sq, Skv, Hq, Hkv, D, causal, window = case
    q_offset = Skv - Sq if causal else 0
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, block_q=64, block_k=64,
                              interpret=True)
    assert out.shape == q.shape
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_linear_scan_padded_length_y_and_state_vs_ref():
    """S = 100 with chunk = 32 pads to 128; padded steps are identities for
    the recurrence (log_w = 0, k = 0), so both y and the final state must
    match the unpadded oracle."""
    r, k, v, log_w, u, s0 = _wkv_inputs(2, 100, 2, 32, seed=5)
    y, s_fin = ops.linear_scan(r, k, v, log_w, u, s0, chunk=32,
                               interpret=True)
    assert y.shape == r.shape
    y_ref, s_ref = wkv_ref(r, k, v, log_w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_fused_epilogue_vs_ref():
    """out_scale multiply + residual add are fused into the kernel epilogue;
    result must equal out_scale * ref + residual."""
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    q = jax.random.normal(ks[0], (1, 128, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.float32)
    res = jax.random.normal(ks[3], (1, 128, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, out_scale=0.5,
                              residual=res, interpret=True)
    ref = 0.5 * attention_ref(q, k, v, causal=True) + res
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_fused_epilogue_padded_vs_ref():
    """The residual rides through the pad/slice wrapper too."""
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (1, 100, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 100, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 100, 2, 64), jnp.float32)
    res = jax.random.normal(ks[3], (1, 100, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, out_scale=2.0,
                              residual=res, block_q=64, block_k=64,
                              interpret=True)
    ref = 2.0 * attention_ref(q, k, v, causal=True) + res
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------- autotuner tile coverage
def _reachable_attention_tiles():
    """Every distinct (block_q, block_k) the tuner can pick across the 12
    kernel-bench configs' validated candidate sets."""
    from repro.kernels import autotune as at
    tiles = set()
    for c in ATTN_CONFIGS:
        for cand in at.attention_candidates(c["Sq"], c["Skv"], c["D"],
                                            c["Dv"], jnp.bfloat16):
            tiles.add((cand.block_q, cand.block_k))
    return sorted(tiles)


def test_reachable_attention_tiles_all_match_ref():
    """Union sweep: any tile the autotuner can select for any bench config
    must be numerically safe.  All reachable tiles are powers of two <= 512,
    so one S = 512 decoder shape exercises each exactly once."""
    tiles = _reachable_attention_tiles()
    assert len(tiles) >= 15  # the ladder really is being swept
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (1, 512, 2, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 512, 2, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 512, 2, 64), jnp.bfloat16)
    ref = np.asarray(attention_ref(q, k, v, causal=True), np.float32)
    for bq, bk in tiles:
        assert 512 % bq == 0 and 512 % bk == 0
        out = ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                  block_k=bk, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), ref, **_tol(jnp.bfloat16),
            err_msg=f"tile ({bq}, {bk}) diverges from the oracle")


def _extreme_tiles(cands):
    by_area = sorted(cands, key=lambda c: (c.block_q * c.block_k, c.block_q))
    return {(t.block_q, t.block_k) for t in (by_area[0], by_area[-1])}


@pytest.mark.parametrize(
    "cfg", [pytest.param(c, id=c["name"]) for c in ATTN_CONFIGS])
def test_bench_config_extreme_tiles_vs_ref(cfg):
    """Per bench config (GQA ratios, MLA asymmetric head dims, windows):
    parity at the smallest and largest candidate tiles — the extremes
    bracket everything the tuner can return for that shape."""
    from repro.kernels import autotune as at
    cands = at.attention_candidates(cfg["Sq"], cfg["Skv"], cfg["D"],
                                    cfg["Dv"], jnp.bfloat16)
    assert cands, f"no candidates for {cfg['name']}"
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (cfg["B"], cfg["Sq"], cfg["Hq"], cfg["D"]),
                          jnp.bfloat16)
    k = jax.random.normal(ks[1], (cfg["B"], cfg["Skv"], cfg["Hkv"], cfg["D"]),
                          jnp.bfloat16)
    v = jax.random.normal(ks[2], (cfg["B"], cfg["Skv"], cfg["Hkv"],
                                  cfg["Dv"]), jnp.bfloat16)
    q_offset = cfg["Skv"] - cfg["Sq"] if cfg["causal"] else 0
    ref = np.asarray(attention_ref(q, k, v, causal=cfg["causal"],
                                   window=cfg["window"], q_offset=q_offset),
                     np.float32)
    for bq, bk in sorted(_extreme_tiles(cands)):
        out = ops.flash_attention(q, k, v, causal=cfg["causal"],
                                  window=cfg["window"], q_offset=q_offset,
                                  block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), ref, **_tol(jnp.bfloat16),
            err_msg=f"{cfg['name']} tile ({bq}, {bk})")


def test_wkv_all_chunk_candidates_vs_ref():
    """Every chunk the tuner can pick for the bench WKV shape matches the
    oracle (including s_fin)."""
    from repro.kernels import autotune as at
    c = WKV_CONFIGS[0]
    cands = at.scan_candidates(c["S"], c["N"], jnp.float32)
    assert len(cands) >= 3
    r, k, v, log_w, u, s0 = _wkv_inputs(c["B"], c["S"], c["H"], c["N"],
                                        seed=7)
    y_ref, s_ref = wkv_ref(r, k, v, log_w, u, s0)
    for cand in cands:
        y, s_fin = ops.linear_scan(r, k, v, log_w, u, s0, chunk=cand.chunk,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"chunk {cand.chunk}")
        np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s_ref),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"chunk {cand.chunk} s_fin")


def test_attention_core_vs_ref_banded():
    """models.attention.attention_core (banded SWA streaming) vs oracle."""
    from repro.models.attention import attention_core
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, H, D, W = 1, 4096, 2, 32, 256
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    out = attention_core(q, k, v, pos, pos, causal=True, window=W)
    ref = attention_ref(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
