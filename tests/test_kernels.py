"""Pallas kernel validation: shape/dtype sweeps, interpret-mode kernel vs the
pure-jnp oracle (assignment requirement: per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import attention_ref, wkv_ref
from repro.models.recurrent import wkv_chunked


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


ATTN_CASES = [
    # (B, Sq, Skv, Hq, Hkv, D, causal, window, dtype)
    (1, 128, 128, 2, 2, 64, True, 0, jnp.float32),
    (2, 256, 256, 4, 1, 64, True, 0, jnp.float32),   # MQA
    (2, 256, 256, 8, 2, 32, True, 0, jnp.float32),   # GQA 4:1
    (1, 128, 384, 2, 2, 64, True, 0, jnp.float32),   # q_offset continuation
    (1, 256, 256, 2, 2, 64, True, 128, jnp.float32),  # sliding window
    (1, 256, 256, 2, 1, 64, True, 64, jnp.float32),   # narrow window + MQA
    (1, 128, 128, 2, 2, 64, False, 0, jnp.float32),   # bidirectional (encoder)
    (2, 256, 256, 4, 4, 128, True, 0, jnp.bfloat16),
    (1, 384, 384, 2, 2, 256, True, 0, jnp.bfloat16),  # gemma head_dim
    (1, 256, 256, 4, 2, 80, True, 128, jnp.bfloat16),  # danube head_dim + SWA
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_vs_ref(case):
    B, Sq, Skv, Hq, Hkv, D, causal, window, dtype = case
    q_offset = Skv - Sq
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("block", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shapes(block):
    bq, bk = block
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    from repro.kernels.flash_attention import flash_attention
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


WKV_CASES = [
    # (B, S, H, N, chunk)
    (1, 64, 2, 16, 16),
    (2, 128, 2, 32, 32),
    (1, 128, 4, 64, 64),
    (2, 96, 2, 16, 32),  # chunk > remainder handling (96 % 32 == 0)
]


def _wkv_inputs(B, S, H, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (B, S, H, N), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, N), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, N), jnp.float32)
    # realistic decays: log_w = -exp(w_raw), w_raw in [-6, 0]
    w_raw = jax.random.uniform(ks[3], (B, S, H, N), jnp.float32, -6.0, 0.0)
    log_w = -jnp.exp(w_raw)
    u = jax.random.normal(ks[4], (H, N), jnp.float32) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, N, N), jnp.float32) * 0.5
    return r, k, v, log_w, u, s0


@pytest.mark.parametrize("case", WKV_CASES)
def test_linear_scan_kernel_vs_ref(case):
    B, S, H, N, chunk = case
    r, k, v, log_w, u, s0 = _wkv_inputs(B, S, H, N)
    y, s_fin = ops.linear_scan(r, k, v, log_w, u, s0, chunk=chunk,
                               interpret=True)
    y_ref, s_ref = wkv_ref(r, k, v, log_w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_wkv_chunked_xla_path_vs_ref():
    """The XLA chunked-parallel path used in model code must match the oracle."""
    r, k, v, log_w, u, s0 = _wkv_inputs(2, 160, 2, 32, seed=3)
    y, s_fin = wkv_chunked(r, k, v, log_w, u, s0, chunk=32)
    y_ref, s_ref = wkv_ref(r, k, v, log_w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_attention_core_vs_ref_banded():
    """models.attention.attention_core (banded SWA streaming) vs oracle."""
    from repro.models.attention import attention_core
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, H, D, W = 1, 4096, 2, 32, 256
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    out = attention_core(q, k, v, pos, pos, causal=True, window=W)
    ref = attention_ref(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
