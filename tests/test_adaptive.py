"""Closed-loop adaptation: online cost model (scalar/batch bit-identity),
drift detection, circuit breakers, retry backoff, telemetry ring buffer and
end-to-end drift-triggered replanning."""
import numpy as np
import pytest

from repro.core import (AdaptiveConfig, AssetGraph, CircuitBreaker,
                        ComputeProfile, CostModel, DriftDetector,
                        DynamicClientFactory, MessageReader, Objective,
                        OnlineCostModel, RetryPolicy, RunCoordinator,
                        RunPlanner, SimulatedClusterClient, SlotConfig,
                        StaticPartitions, asset, default_catalog)

CATALOG = default_catalog()
PLATFORMS = list(CATALOG.values())


def _specs():
    light = asset(name="light",
                  compute=ComputeProfile(work_chip_hours=2.3,
                                         speedup_class="light"))(lambda ctx: 1)
    heavy = asset(name="heavy",
                  compute=ComputeProfile(work_chip_hours=2200.0,
                                         speedup_class="scan"))(lambda ctx: 1)
    analytic = asset(name="analytic",
                     compute=ComputeProfile(flops=1e18, bytes_hbm=1e14,
                                            min_chips=4))(lambda ctx: 1)
    return [light, heavy, analytic]


def _assert_scalar_batch_agree(model):
    """estimate_batch must equal scalar estimate cell-for-cell, bit-exact."""
    specs = _specs()
    batch = model.estimate_batch(specs, PLATFORMS)
    for i, s in enumerate(specs):
        for j, p in enumerate(PLATFORMS):
            est = model.estimate(s, p)
            assert batch["feasible"][i, j] == est.feasible
            assert batch["duration_s"][i, j] == est.duration_s
            assert batch["compute_s"][i, j] == est.compute_s
            assert batch["base_usd"][i, j] == est.base_usd
            if est.feasible:
                assert batch["surcharge_usd"][i, j] == est.surcharge_usd
                assert batch["storage_usd"][i, j] == est.storage_usd
                assert batch["total_usd"][i, j] == est.total_usd
                assert batch["expected_usd"][i, j] == \
                    model.expected_cost_with_retries(est, p, s.name)
                assert batch["sched_duration_s"][i, j] == \
                    model.schedule_duration(est, p, s.name)


# --------------------------------------------------------------- cost model
def test_pristine_online_model_bit_identical_to_static():
    """Zero observations: every scalar field and every batch column of the
    online model equals the static model's, bit for bit."""
    static, online = CostModel(), OnlineCostModel()
    specs = _specs()
    for s in specs:
        for p in PLATFORMS:
            es, eo = static.estimate(s, p), online.estimate(s, p)
            assert es == eo
            if es.feasible:
                assert static.expected_cost_with_retries(es, p, s.name) == \
                    online.expected_cost_with_retries(eo, p, s.name)
                assert static.schedule_duration(es, p, s.name) == \
                    online.schedule_duration(eo, p, s.name)
    sb = static.estimate_batch(specs, PLATFORMS)
    ob = online.estimate_batch(specs, PLATFORMS)
    for col in sb:
        assert np.array_equal(sb[col], ob[col]), col


def test_scalar_batch_agree_after_observations():
    model = OnlineCostModel()
    for i in range(8):
        model.observe("light", "pod-spot", "success",
                      predicted_s=100.0, realized_s=100.0 * (1.5 + 0.1 * i))
        model.observe("heavy", "pod-spot",
                      "preemption" if i % 3 == 0 else "success",
                      predicted_s=500.0, realized_s=1400.0)
        model.observe("light", "pod-premium", "failure")
    _assert_scalar_batch_agree(model)


def test_scalar_batch_agree_property():
    """Arbitrary telemetry replays never break scalar/batch bit-identity."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    obs = st.tuples(
        st.sampled_from(["light", "heavy", "analytic", "unseen"]),
        st.sampled_from(sorted(CATALOG)),
        st.sampled_from(["success", "failure", "preemption", "cancelled"]),
        st.floats(0.0, 1e4),
        st.floats(0.0, 1e9))

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(st.lists(obs, max_size=40))
    def check(replay):
        model = OnlineCostModel()
        for a, p, outcome, pred, real in replay:
            model.observe(a, p, outcome, predicted_s=pred, realized_s=real)
        _assert_scalar_batch_agree(model)

    check()


def test_duration_ratio_learning_blend_and_clamp():
    cfg = AdaptiveConfig(prior_strength=4.0)
    model = OnlineCostModel(config=cfg)
    assert model.duration_ratio("light", "pod-spot") == 1.0
    for _ in range(6):
        model.observe("light", "pod-spot", "success",
                      predicted_s=100.0, realized_s=300.0)
    r = model.duration_ratio("light", "pod-spot")
    assert 1.5 < r < 3.0  # shrunk toward the prior, pulled toward 3.0
    # platform-level generalization: an asset never observed on pod-spot
    # still inherits the platform's drift (that is what lets a replan move
    # big tasks *before* they burn an attempt on a drifted platform)
    assert model.duration_ratio("heavy", "pod-spot") > 1.3
    # ...but other platforms stay pristine
    assert model.duration_ratio("light", "pod-premium") == 1.0
    # clamping: absurd observed ratios cannot explode pricing
    for _ in range(50):
        model.observe("light", "pod-spot", "success",
                      predicted_s=1.0, realized_s=1e6)
    assert model.duration_ratio("light", "pod-spot") == cfg.ratio_max


def test_online_p_ok_learns_failures():
    model = OnlineCostModel()
    p = CATALOG["pod-premium"]
    prior = p.p_success()
    for _ in range(10):
        model.observe("light", "pod-premium", "failure")
    assert model._p_ok(p, "light") < prior
    # cross-asset: the platform-level success EWMA drags other assets too
    assert model._p_ok(p, "heavy") < prior
    assert model._p_ok(CATALOG["pod-spot"], "light") == \
        CATALOG["pod-spot"].p_success()


# ------------------------------------------------------------------ drift
def test_drift_detector_ratio_breach_and_rebaseline():
    cfg = AdaptiveConfig(min_observations=3, ratio_threshold=1.4)
    model = OnlineCostModel(config=cfg)
    det = DriftDetector(model, cfg)
    for _ in range(4):
        model.observe("light", "pod-spot", "success",
                      predicted_s=100.0, realized_s=300.0)
        det.observe("light", "pod-spot", "success")
    reasons = det.check()
    assert any("duration drift light@pod-spot" in r for r in reasons)
    det.mark_replanned()  # the new plan already prices these beliefs
    assert det.check() == []


def test_drift_detector_failure_burst_and_preemption_streak():
    cfg = AdaptiveConfig(failure_burst=3, preemption_streak=3)
    det = DriftDetector(OnlineCostModel(config=cfg), cfg)
    for _ in range(3):
        det.observe("a", "pod-spot", "failure")
    assert any("failure burst on pod-spot" in r for r in det.check())
    det.mark_replanned()
    for _ in range(3):
        det.observe("a", "multipod-spot", "preemption")
    assert any("preemption streak on multipod-spot" in r
               for r in det.check())
    # a success interrupts the streak
    det.mark_replanned()
    det.observe("a", "multipod-spot", "preemption")
    det.observe("a", "multipod-spot", "preemption")
    det.observe("a", "multipod-spot", "success")
    det.observe("a", "multipod-spot", "preemption")
    assert det.check() == []


# ----------------------------------------------------------------- breaker
def test_circuit_breaker_state_machine():
    br = CircuitBreaker("pod-spot", failures=3, cooldown_s=10.0)
    t = 100.0
    assert br.record("failure", t) is None
    assert br.record("failure", t) is None
    assert br.allow(t)
    assert br.record("failure", t) == "open"  # 3rd consecutive trips it
    assert not br.allow(t + 9.9)
    assert br.allow(t + 10.0)  # cooldown elapsed -> half-open
    assert br.state == "half-open"
    br.note_launch(t + 10.0)
    assert not br.allow(t + 10.1)  # single probe in flight
    assert br.record("failure", t + 11.0) == "open"  # probe failed
    assert not br.allow(t + 12.0)
    assert br.allow(t + 21.0)  # second cooldown
    br.note_launch(t + 21.0)
    assert br.record("success", t + 22.0) == "closed"
    assert br.allow(t + 22.0)
    assert br.trips == 2


def test_circuit_breaker_preemptions_neutral():
    br = CircuitBreaker("pod-spot", failures=2)
    assert br.record("failure", 0.0) is None
    # preemptions neither trip (expected on spot) nor reset (no evidence of
    # health) the consecutive-failure count
    assert br.record("preemption", 0.0) is None
    assert br.state == "closed"
    assert br.record("failure", 0.0) == "open"


# ----------------------------------------------------------------- backoff
def test_retry_backoff_capped_exponential():
    r = RetryPolicy(max_attempts=6, backoff_s=0.2, backoff_cap_s=1.0,
                    jitter=0.0)
    assert [r.delay_s(a) for a in range(1, 6)] == [0.2, 0.4, 0.8, 1.0, 1.0]
    assert RetryPolicy(backoff_s=0.0).delay_s(3) == 0.0


def test_retry_backoff_jitter_deterministic_and_bounded():
    r = RetryPolicy(backoff_s=0.2, backoff_cap_s=30.0, jitter=0.25)
    a = r.delay_s(2, ("edges", "p0"))
    assert a == r.delay_s(2, ("edges", "p0"))  # no RNG state: replayable
    b = r.delay_s(2, ("edges", "p1"))
    assert a != b  # retries across tasks decorrelate (no thundering herd)
    for key in [("edges", "p0"), ("edges", "p1"), ("nodes", "p0")]:
        for attempt in range(1, 5):
            d = r.delay_s(attempt, key)
            base = min(0.2 * 2.0 ** (attempt - 1), 30.0)
            assert base * 0.75 <= d <= base * 1.25


# --------------------------------------------------------------- telemetry
def _feed(reader):
    for i in range(30):
        reader.emit("r1", f"a{i % 3}", "p", "pod-spot", "COST",
                    total_usd=float(i), duration_s=10.0 * i, outcome="success")
        reader.emit("r1", f"a{i % 3}", "p", "pod-spot", "SUCCESS",
                    duration_s=10.0 * i)
        if i % 5 == 0:
            reader.emit("r1", f"a{i % 3}", "p", "multipod-spot", "FAILURE",
                        failure_kind="preemption" if i % 2 else "failure")
        if i % 7 == 0:
            reader.emit("r1", f"a{i % 3}", "p", "cache", "CACHE_HIT")


def test_ring_buffer_compaction_preserves_aggregates():
    bounded, unbounded = MessageReader(max_events=16), MessageReader()
    _feed(bounded)
    _feed(unbounded)
    assert len(bounded.events()) <= 16
    assert bounded.evicted_events > 0
    assert unbounded.evicted_events == 0
    assert bounded.outcome_counts() == unbounded.outcome_counts()
    assert bounded.total_cost() == pytest.approx(unbounded.total_cost())
    assert bounded.total_cost("pod-spot") == \
        pytest.approx(unbounded.total_cost("pod-spot"))
    assert bounded.cost_by_asset() == pytest.approx(unbounded.cost_by_asset())
    stats_b = bounded.cache_stats("r1")
    stats_u = unbounded.cache_stats("r1")
    assert stats_b["cache_hits"] == stats_u["cache_hits"]
    assert stats_b["executed"] == stats_u["executed"]
    # compacted durations degrade gracefully to the lifetime mean
    assert bounded.median_duration("a0") is not None


def test_events_since_cursor():
    reader = MessageReader()
    reader.emit("r", "a", "p", "x", "START")
    reader.emit("r", "a", "p", "x", "SUCCESS", duration_s=1.0)
    first = reader.events_since(0)
    assert [e.seq for e in first] == [0, 1]
    cursor = first[-1].seq + 1
    assert reader.events_since(cursor) == []
    reader.emit("r", "a", "p", "x", "COST", total_usd=1.0)
    nxt = reader.events_since(cursor)
    assert [e.kind for e in nxt] == ["COST"]


def test_max_events_validation():
    with pytest.raises(ValueError):
        MessageReader(max_events=1)


# ----------------------------------------------------------------- planner
def _pair_graph(parts):
    a = asset(name="a", partitions=parts,
              compute=ComputeProfile(work_chip_hours=0.2))(lambda ctx: 1)
    b = asset(name="b", deps=("a",), partitions=parts,
              compute=ComputeProfile(work_chip_hours=150.0,
                                     speedup_class="scan"),
              retry=RetryPolicy(max_attempts=4, backoff_s=0.0,
                                failover_after=2))(lambda ctx, a: a + 1)
    return AssetGraph([a, b])


def test_planner_exclude_drops_tasks():
    parts = StaticPartitions(("p0", "p1"))
    graph = _pair_graph(parts)
    factory = DynamicClientFactory(default_catalog(), CostModel(),
                                   Objective.balanced())
    planner = RunPlanner(graph, factory, store=None)
    full = planner.plan(["b"])
    assert set(full.choices) == {("a", "p0"), ("a", "p1"),
                                 ("b", "p0"), ("b", "p1")}
    # mid-run replan: a's tasks already done/in flight (predecessor-closed)
    part = planner.plan(["b"], exclude={("a", "p0"), ("a", "p1")})
    assert part.feasible
    assert set(part.choices) == {("b", "p0"), ("b", "p1")}


# -------------------------------------------------------------- end to end
def _fleet_factory(objective, builder):
    catalog = {k: p for k, p in default_catalog().items() if k != "local"}
    return DynamicClientFactory(catalog, CostModel(), objective,
                                client_builder=builder)


def test_adaptive_replan_migrates_before_big_tasks_launch():
    """pod-spot runs 4x slower than the catalog promises: the small ``a``
    tasks teach the online model, drift fires, and the big ``b`` tasks are
    replanned onto honest capacity before ever launching on pod-spot."""
    parts = StaticPartitions(("p0", "p1"))
    graph = _pair_graph(parts)

    def slow_spot(p):
        return SimulatedClusterClient(
            p, sim_time_scale=2e-5, failure_rate=0.0, preemption_rate=0.0,
            duration_bias=4.0 if p.name == "pod-spot" else 1.0)

    cfg = AdaptiveConfig(min_observations=1, prior_strength=1.0,
                         replan_cooldown_s=0.0)
    static = RunCoordinator(
        _pair_graph(parts), _fleet_factory(Objective.min_cost(), slow_spot),
        use_cache=False, enable_speculation=False)
    plan = static.plan("b")
    assert {c.platform for c in plan.choices.values()} == {"pod-spot"}

    reader = MessageReader()
    coord = RunCoordinator(
        graph, _fleet_factory(Objective.min_cost(), slow_spot),
        reader=reader, use_cache=False, enable_speculation=False,
        slots=SlotConfig(max_concurrent=2, platform_slots=2,
                         elastic_max_slots=2),
        adaptive=cfg)
    report = coord.materialize("b", run_id="drift-e2e", plan=plan)
    assert report.ok
    replans = [e for e in reader.events() if e.kind == "REPLAN"]
    assert replans and replans[0].payload["adopted"]
    assert any("duration drift" in r or "drift" in r
               for r in replans[0].payload["reasons"])
    b_platforms = {r.platform for r in report.records if r.asset == "b"}
    assert "pod-spot" not in b_platforms  # the migration actually happened


def test_breaker_evicts_sick_platform_fleet_wide():
    """pod-spot hard-fails every attempt: after ``breaker_failures``
    consecutive failures the breaker opens and *every* subsequent task is
    denied pod-spot — without burning its own per-task retry budget there."""
    parts = StaticPartitions(tuple(f"p{i}" for i in range(4)))
    graph = _pair_graph(parts)

    def broken_spot(p):
        return SimulatedClusterClient(
            p, failure_rate=1.0 if p.name == "pod-spot" else 0.0,
            preemption_rate=0.0)

    cfg = AdaptiveConfig(breaker_failures=2, breaker_cooldown_s=600.0,
                         min_observations=100)  # isolate the breaker path
    reader = MessageReader()
    coord = RunCoordinator(
        graph, _fleet_factory(Objective.min_cost(), broken_spot),
        reader=reader, use_cache=False, enable_speculation=False,
        adaptive=cfg)
    report = coord.materialize("b", run_id="breaker-e2e")
    assert report.ok
    opened = [e for e in reader.events()
              if e.kind == "BREAKER" and e.payload["state"] == "open"]
    assert [e.platform for e in opened][:1] == ["pod-spot"]
    # every task finished off the sick platform
    assert all(r.attempts[-1].platform != "pod-spot"
               for r in report.records)
    # fleet-wide denial: pod-spot saw at most breaker_failures + a couple
    # in-flight attempts, NOT len(tasks) * failover_after attempts
    spot_failures = sum(
        1 for r in report.records for a in r.attempts
        if a.platform == "pod-spot")
    assert spot_failures <= 4


def test_zero_drift_adaptive_run_matches_static():
    """With honest platforms the closed loop must not replan or diverge."""
    parts = StaticPartitions(("p0", "p1"))

    def honest(p):
        return SimulatedClusterClient(p, failure_rate=0.0,
                                      preemption_rate=0.0)

    plan = RunCoordinator(
        _pair_graph(parts), _fleet_factory(Objective.min_cost(), honest),
        use_cache=False, enable_speculation=False).plan("b")
    reports = []
    for adaptive in (None, AdaptiveConfig()):
        reader = MessageReader()
        coord = RunCoordinator(
            _pair_graph(parts), _fleet_factory(Objective.min_cost(), honest),
            reader=reader, use_cache=False, enable_speculation=False,
            adaptive=adaptive)
        reports.append(coord.materialize("b", run_id="parity", plan=plan))
        assert not [e for e in reader.events() if e.kind == "REPLAN"]
    static, closed = reports
    assert static.ok and closed.ok
    assert {(r.asset, r.partition, r.platform) for r in static.records} == \
        {(r.asset, r.partition, r.platform) for r in closed.records}
    assert static.total_cost == pytest.approx(closed.total_cost)
