"""Scheduling-engine unit tests: PERT forward/backward passes, O(cone)
incremental retiming equivalence, finite-capacity list scheduling (slots=1
serialization, capacity effects), vectorized pricing parity, and the
planner edge cases (empty DAG, single task, all-critical chain)."""
import numpy as np
import pytest

from repro.core import (AssetGraph, ComputeProfile, CostModel,
                        DynamicClientFactory, Objective, RunPlanner,
                        ScheduleEngine, SlotConfig, asset, default_catalog,
                        plan_run, task_dag)


def _eng(edges: dict[str, list[str]], slots=None) -> ScheduleEngine:
    """Engine from {task: [preds]} over single-partition keys."""
    names = list(edges)
    keys = [(n, "__all__") for n in names]
    preds = {(n, "__all__"): [(p, "__all__") for p in edges[n]]
             for n in names}
    return ScheduleEngine(keys, preds, slots)


def _spec(name, work, deps=(), parts=None, hint=None):
    return asset(name=name, deps=deps, partitions=parts, platform_hint=hint,
                 compute=ComputeProfile(work_chip_hours=work, min_chips=8))(
        lambda ctx, **kw: name)


def make_factory(objective=None):
    return DynamicClientFactory(default_catalog(), CostModel(),
                                objective or Objective.balanced(600.0))


# ------------------------------------------------------------ PERT passes
def test_forward_backward_chain():
    e = _eng({"a": [], "b": ["a"], "c": ["b"]})
    e.load([1.0, 2.0, 3.0])
    assert e.makespan_s == 6.0
    assert np.allclose(e.slack(), 0.0)
    assert e.critical_mask().all()


def test_fanout_slack():
    e = _eng({"src": [], "big": ["src"], "small": ["src"],
              "sink": ["big", "small"]})
    e.load([1.0, 10.0, 2.0, 1.0])
    assert e.makespan_s == 12.0
    slack = dict(zip([k[0] for k in e.keys], e.slack()))
    assert slack["big"] == 0.0 and slack["src"] == 0.0 and slack["sink"] == 0.0
    assert slack["small"] == pytest.approx(8.0)


def test_incremental_retime_matches_full_recompute():
    """Random-ish DAG: every set_duration must leave finish/makespan equal
    to a from-scratch forward pass."""
    rng = np.random.RandomState(7)
    n = 60
    edges = {"t0": []}
    for i in range(1, n):
        k = rng.randint(0, min(i, 4))
        preds = sorted(rng.choice(i, size=k, replace=False).tolist())
        edges[f"t{i}"] = [f"t{p}" for p in preds]
    e = _eng(edges)
    durs = rng.uniform(0.5, 5.0, size=n).tolist()
    e.load(list(durs))
    ref = _eng(edges)
    for _ in range(100):
        i = int(rng.randint(0, n))
        durs[i] = float(rng.uniform(0.1, 8.0))
        e.set_duration(i, durs[i])
        ref.load(list(durs))
        assert e.makespan_s == pytest.approx(ref.makespan_s)
        assert np.allclose(e.slack(), ref.slack())


def test_try_duration_undo_restores_state():
    e = _eng({"a": [], "b": ["a"], "c": ["b"]})
    e.load([1.0, 1.0, 1.0])
    slack_before = e.slack().copy()
    ms, undo = e.try_duration(1, 100.0)
    assert ms == pytest.approx(102.0)
    undo()
    assert e.makespan_s == pytest.approx(3.0)
    assert np.allclose(e.slack(), slack_before)
    # slack cache survived the undone trial (no recompute needed)
    assert e._slack is not None


# ---------------------------------------------------------- slot schedule
def test_slots_one_serializes_everything():
    e = _eng({f"t{i}": [] for i in range(7)},
             slots=SlotConfig(max_concurrent=1, platform_slots=1,
                              elastic_max_slots=1))
    e.load([1.0] * 7, ["p"] * 7)
    sched = e.slot_schedule()
    assert sched.makespan_s == pytest.approx(7.0)
    assert sched.peak_in_use == {"p": 1}


def test_slot_capacity_waves():
    """9 independent unit tasks on one platform with width 4 -> 3 waves."""
    e = _eng({f"t{i}": [] for i in range(9)},
             slots=SlotConfig(max_concurrent=16, elastic_max_slots=4))
    e.load([1.0] * 9, ["p"] * 9)
    sched = e.slot_schedule()
    assert sched.makespan_s == pytest.approx(3.0)
    assert sched.peak_in_use == {"p": 4}
    assert sched.wait_s_total > 0.0


def test_global_cap_binds_across_platforms():
    e = _eng({f"t{i}": [] for i in range(8)},
             slots=SlotConfig(max_concurrent=4, elastic_max_slots=8))
    e.load([1.0] * 8, ["p", "q"] * 4)
    assert e.slot_schedule().makespan_s == pytest.approx(2.0)


def test_infinite_width_matches_pert():
    e = _eng({"a": [], "b": ["a"], "c": ["a"], "d": ["b", "c"]})
    e.load([1.0, 5.0, 2.0, 1.0], ["p"] * 4)
    assert e.slot_schedule(slots=None).makespan_s == e.makespan_s


def test_slot_makespan_monotone_in_capacity_for_fanout():
    """On fan-out DAGs (independent branches between chokepoints) growing
    slot width never increases the makespan."""
    rng = np.random.RandomState(3)
    edges = {"src": []}
    for i in range(20):
        edges[f"b{i:02d}"] = ["src"]
    edges["sink"] = [f"b{i:02d}" for i in range(20)]
    durs = [1.0] + rng.uniform(0.2, 5.0, size=20).tolist() + [1.0]
    prev = None
    for width in (1, 2, 3, 5, 8, 16, 32):
        e = _eng(edges, slots=SlotConfig(max_concurrent=64,
                                         elastic_max_slots=width))
        e.load(list(durs), ["p"] * 22)
        ms = e.slot_schedule().makespan_s
        if prev is not None:
            assert ms <= prev + 1e-9
        prev = ms


def _reference_list_schedule(e: ScheduleEngine, cfg: SlotConfig):
    """Naive transcription of the event-driven list scheduler — the oracle
    the fast paths (PERT-feasible return, single-pool FIFO) must match."""
    import heapq
    n = e.n
    indeg = [len(p) for p in e.preds]
    plats = sorted(set(e._platform))
    queues = {p: [] for p in plats}
    in_use = {p: 0 for p in plats}
    cap = {p: cfg.capacity(p) for p in plats}
    ready_at = [0.0] * n
    start = np.zeros(n)
    finish = np.zeros(n)
    running, giu, t, wait = [], 0, 0.0, 0.0
    for i in range(n):
        if indeg[i] == 0:
            heapq.heappush(queues[e._platform[i]], i)
    n_done = 0
    while n_done < n:
        while giu < cfg.max_concurrent:
            best = None
            for p in plats:
                if queues[p] and in_use[p] < cap[p] and (
                        best is None or queues[p][0] < queues[best][0]):
                    best = p
            if best is None:
                break
            i = heapq.heappop(queues[best])
            start[i] = t
            finish[i] = t + e._dur[i]
            wait += t - ready_at[i]
            in_use[best] += 1
            giu += 1
            heapq.heappush(running, (finish[i], i))
        t, i = heapq.heappop(running)
        while True:
            in_use[e._platform[i]] -= 1
            giu -= 1
            n_done += 1
            for s in e.succs[i]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready_at[s] = t
                    heapq.heappush(queues[e._platform[s]], s)
            if running and running[0][0] <= t:
                _, i = heapq.heappop(running)
            else:
                break
    return start, finish, wait


def test_slot_schedule_fast_paths_match_reference():
    """Randomized DAGs x slot configs: whatever path slot_schedule takes
    (PERT-feasible shortcut, single-pool FIFO, general event loop), the
    start/finish/wait must equal the naive list scheduler's."""
    rng = np.random.RandomState(11)
    configs = [
        SlotConfig(max_concurrent=8, platform_slots=2, elastic_max_slots=8),
        SlotConfig(max_concurrent=3, platform_slots=1, elastic_max_slots=2),
        SlotConfig(max_concurrent=2, platform_slots=1, elastic_max_slots=1),
        SlotConfig(max_concurrent=500, platform_slots=2,
                   elastic_max_slots=500),  # wide: PERT-feasible shortcut
    ]
    for trial in range(25):
        n = int(rng.randint(2, 35))
        edges = {"t0": []}
        for i in range(1, n):
            k = rng.randint(0, min(i, 4))
            preds = sorted(rng.choice(i, size=k, replace=False).tolist())
            edges[f"t{i}"] = [f"t{p}" for p in preds]
        e = _eng(edges)
        # mix in zero durations: they must route around the PERT shortcut
        durs = rng.uniform(0.5, 5.0, size=n)
        durs[rng.rand(n) < 0.2] = 0.0
        plats = [("aws", "gcp", "local")[int(x)]
                 for x in rng.randint(0, 3, size=n)]
        e.load(durs.tolist(), plats)
        for cfg in configs:
            got = e.slot_schedule(cfg)
            start, finish, wait = _reference_list_schedule(e, cfg)
            assert np.allclose(got.start, start), (trial, cfg)
            assert np.allclose(got.finish, finish), (trial, cfg)
            assert got.wait_s_total == pytest.approx(wait), (trial, cfg)


def test_pert_feasible_shortcut_returns_pert_schedule():
    """Wide caps + positive durations: the shortcut fires and the schedule
    is exactly the infinite-width forward pass with zero queueing."""
    e = _eng({"a": [], "b": ["a"], "c": ["a"], "d": ["b", "c"]})
    e.load([1.0, 5.0, 2.0, 1.0], ["p"] * 4)
    cfg = SlotConfig(max_concurrent=8, platform_slots=8, elastic_max_slots=8)
    fast = e._pert_feasible_schedule(cfg)
    assert fast is not None
    sched = e.slot_schedule(cfg)
    assert sched.makespan_s == e.makespan_s
    assert sched.wait_s_total == 0.0
    assert np.allclose(sched.finish - sched.start,
                       np.asarray(e._dur))
    assert sched.peak_in_use == {"p": 2}  # b and c overlap


def test_pert_shortcut_declines_zero_durations():
    e = _eng({"a": [], "b": ["a"]})
    e.load([0.0, 1.0], ["p"] * 2)
    cfg = SlotConfig(max_concurrent=8, platform_slots=8, elastic_max_slots=8)
    assert e._pert_feasible_schedule(cfg) is None


def test_try_duration_fanout_sink_edge_updates():
    """High-indegree sink: growing/shrinking one branch must retime the sink
    correctly through the O(1) edge-update path (max increase, max decrease
    with rescan, and below-max no-ops)."""
    width = 50
    edges = {"src": []}
    for i in range(width):
        edges[f"b{i:02d}"] = ["src"]
    edges["sink"] = [f"b{i:02d}" for i in range(width)]
    e = _eng(edges)
    durs = [1.0] + [float(i % 7 + 1) for i in range(width)] + [2.0]
    e.load(list(durs))
    base = e.makespan_s
    # grow a non-max branch beyond the max: sink start follows the new max
    ms, undo = e.try_duration(1, 50.0)
    assert ms == pytest.approx(1.0 + 50.0 + 2.0)
    undo()
    assert e.makespan_s == pytest.approx(base)
    # shrink the unique max branch: the sink rescans and lands on the next
    ref = _eng(edges)
    i_max = int(np.argmax(durs[1:width + 1])) + 1
    durs2 = list(durs)
    durs2[i_max] = 0.5
    ref.load(durs2)
    ms, undo = e.try_duration(i_max, 0.5)
    assert ms == pytest.approx(ref.makespan_s)
    undo()
    # grow a branch but keep it below the max: makespan unchanged, O(1) exit
    ms, _undo = e.try_duration(1, durs[1] + 0.1)
    assert ms == pytest.approx(base)


def test_topo_order_violation_rejected():
    keys = [("b", "__all__"), ("a", "__all__")]
    preds = {("b", "__all__"): [("a", "__all__")], ("a", "__all__"): []}
    with pytest.raises(ValueError, match="topologically"):
        ScheduleEngine(keys, preds)


# ------------------------------------------------------- task_dag caching
def test_task_dag_matches_uncached_expansion():
    from repro.core.partitions import (StaticPartitions, dep_partition_keys,
                                       partition_keys)
    parts = StaticPartitions(("p0", "p1", "p2"))
    shards = _spec("shards", 10.0, parts=parts)
    merged = _spec("merged", 5.0, deps=("shards",))
    g = AssetGraph([shards, merged])
    keys, preds = task_dag(g, ["merged"])
    assert keys == [("shards", "p0"), ("shards", "p1"), ("shards", "p2"),
                    ("merged", "__all__")]
    for name, key in keys:
        spec = g[name]
        want = [(d, dk) for d in spec.deps
                for dk in dep_partition_keys(g[d].partitions, key)]
        assert preds[(name, key)] == want
        assert key in partition_keys(spec.partitions)


# --------------------------------------------------- planner edge cases
def test_plan_empty_graph():
    plan = plan_run(AssetGraph([]), make_factory())
    assert plan.feasible
    assert plan.choices == {}
    assert plan.predicted_cost_usd == 0.0
    assert plan.predicted_makespan_s == 0.0
    assert "planned:" in plan.table()


def test_plan_single_task():
    plan = plan_run(AssetGraph([_spec("solo", 50.0)]), make_factory())
    ch = plan.choice("solo", "__all__")
    assert ch is not None and ch.critical
    assert plan.predicted_makespan_s == pytest.approx(
        ch.estimate.duration_s)
    assert plan.predicted_cost_usd <= plan.greedy_cost_usd + 1e-9


def test_plan_all_critical_chain_slots_match_pert():
    specs = [_spec("c0", 30.0)]
    for i in range(1, 6):
        specs.append(_spec(f"c{i}", 30.0, deps=(f"c{i-1}",)))
    plan = plan_run(AssetGraph(specs), make_factory(), ["c5"])
    assert all(c.critical for c in plan.choices.values())
    # a chain never contends: slot-aware == critical-path bound
    assert plan.predicted_makespan_s == pytest.approx(plan.pert_makespan_s)


def test_plan_slots_one_serializes():
    specs = [_spec(f"p{i}", 20.0) for i in range(4)]
    plan = plan_run(AssetGraph(specs), make_factory(),
                    slots=SlotConfig(max_concurrent=1, platform_slots=1,
                                     elastic_max_slots=1))
    total = sum(c.estimate.duration_s for c in plan.choices.values())
    assert plan.predicted_makespan_s == pytest.approx(total)


# ------------------------------------------------- vectorized pricing
def test_estimate_batch_matches_scalar():
    cm = CostModel()
    cat = default_catalog()
    plats = [cat[k] for k in sorted(cat)]
    specs = []
    for i in range(12):
        specs.append(asset(name=f"a{i}", compute=ComputeProfile(
            work_chip_hours=float(i) * 17.3 + 0.4,
            speedup_class=("scan", "shuffle", "light", "train", "serve")[i % 5],
            min_chips=(1, 8, 64, 256, 300)[i % 5],
            memory_gb_per_chip=(0.0, 12.0, 20.0)[i % 3]))(lambda ctx: 0))
    specs.append(asset(name="analytic", compute=ComputeProfile(
        flops=1e18, bytes_hbm=1e15, collective_bytes=1e13))(lambda ctx: 0))
    batch = cm.estimate_batch(specs, plats)
    for i, s in enumerate(specs):
        for j, p in enumerate(plats):
            est = cm.estimate(s, p)
            assert est.feasible == bool(batch["feasible"][i, j])
            if est.feasible:
                # bit-identical, not just close: the planner's plans must not
                # depend on which pricing path ran
                assert est.duration_s == batch["duration_s"][i, j]
                assert est.total_usd == batch["total_usd"][i, j]
                assert cm.expected_cost_with_retries(est, p) == \
                    batch["expected_usd"][i, j]
                # component columns (the planner re-assembles CostEstimate
                # objects from these instead of calling scalar estimate)
                assert est.compute_s == batch["compute_s"][i, j]
                assert est.base_usd == batch["base_usd"][i, j]
                assert est.surcharge_usd == batch["surcharge_usd"][i, j]
                assert est.storage_usd == batch["storage_usd"][i, j]


def test_estimate_batch_empty():
    cm = CostModel()
    cat = default_catalog()
    out = cm.estimate_batch([], list(cat.values()))
    assert out["duration_s"].shape == (0, len(cat))


# ------------------------------------------------------- determinism
def test_plan_is_deterministic_across_insertion_orders():
    """Stable (score, platform, key) tie-breaking: the same DAG must yield
    byte-identical plans regardless of asset insertion order (a proxy for
    hash-seed independence — nothing iterates sets/dicts unsorted)."""
    def build(order):
        specs = {
            "src": _spec("src", 5.0),
            "b0": _spec("b0", 400.0, deps=("src",)),
            "b1": _spec("b1", 40.0, deps=("src",)),
            "b2": _spec("b2", 40.0, deps=("src",)),
            "sink": _spec("sink", 5.0, deps=("b0", "b1", "b2")),
        }
        return AssetGraph([specs[n] for n in order])

    g1 = build(["src", "b0", "b1", "b2", "sink"])
    g2 = build(["sink", "b2", "b1", "b0", "src"])
    p1 = plan_run(g1, make_factory(), ["sink"])
    p2 = plan_run(g2, make_factory(), ["sink"])
    assert p1.table() == p2.table()
    assert {k: v.platform for k, v in p1.choices.items()} == \
        {k: v.platform for k, v in p2.choices.items()}
    # and twice on the same graph object
    p3 = plan_run(g1, make_factory(), ["sink"])
    assert p1.table() == p3.table()


def test_plan_table_truncation_and_summary_footer():
    from repro.core.partitions import StaticPartitions
    parts = StaticPartitions(tuple(f"p{i:03d}" for i in range(80)))
    shards = _spec("shards", 10.0, parts=parts)
    merged = _spec("merged", 5.0, deps=("shards",))
    plan = plan_run(AssetGraph([shards, merged]), make_factory(), ["merged"])
    t = plan.table(max_rows=50)
    assert "more tasks" in t
    assert "asset @ platform" in t
    # truncated: far fewer per-task rows than tasks
    assert t.count("shards[") <= 50
    full = plan.table(max_rows=10_000)
    assert full.count("shards[") == 80
    # RunPlanner used a SlotConfig, so the preview reports slot contention
    assert "slots:" in t


def test_planner_slot_config_defaults_match_coordinator():
    from repro.core import RunCoordinator
    g = AssetGraph([_spec("a", 10.0)])
    coord = RunCoordinator(g, make_factory())
    assert coord.slots == SlotConfig()
    assert RunPlanner(g, make_factory()).slots == coord.slots
