"""DAG-level run planner: critical-path extraction, budget/deadline
constraints (including proven infeasibility), dominance over the greedy
per-task factory, slot-aware makespan agreement with the coordinator, and
the coordinator integration with greedy fallback."""
import pytest

from repro.core import (AssetGraph, ComputeProfile, CostModel,
                        DynamicClientFactory, MessageReader, Objective,
                        RetryPolicy, RunCoordinator,
                        SimulatedClusterClient, StaticPartitions, asset,
                        default_catalog, plan_run)


def _spec(name, work, deps=(), cls="scan", min_chips=8, parts=None,
          retry=None, hint=None):
    return asset(name=name, deps=deps, partitions=parts,
                 retry=retry or RetryPolicy(),
                 platform_hint=hint,
                 compute=ComputeProfile(work_chip_hours=work,
                                        speedup_class=cls,
                                        min_chips=min_chips))(
        lambda ctx, **kw: name)


def fanout_graph(heavy=400.0, light=40.0, width=5):
    """src -> b0(heavy), b1..b{width-1}(light) -> sink."""
    specs = [_spec("src", 5.0)]
    for i in range(width):
        specs.append(_spec(f"b{i}", heavy if i == 0 else light,
                           deps=("src",)))
    specs.append(_spec("sink", 5.0, cls="light",
                       deps=tuple(f"b{i}" for i in range(width))))
    return AssetGraph(specs), ["sink"]


def make_factory(objective=None):
    return DynamicClientFactory(default_catalog(), CostModel(),
                                objective or Objective.balanced(600.0))


def nofail_factory(objective=None):
    return DynamicClientFactory(
        default_catalog(), CostModel(),
        objective or Objective.balanced(600.0),
        client_builder=lambda p: SimulatedClusterClient(
            p, seed=0, failure_rate=0.0, preemption_rate=0.0))


# --------------------------------------------------------- critical path
def test_chain_is_entirely_critical():
    a = _spec("a", 50.0)
    b = _spec("b", 50.0, deps=("a",))
    c = _spec("c", 50.0, deps=("b",))
    plan = plan_run(AssetGraph([a, b, c]), make_factory(), ["c"])
    assert all(ch.critical for ch in plan.choices.values())
    assert all(ch.slack_s == pytest.approx(0.0, abs=1e-6)
               for ch in plan.choices.values())


def test_fanout_critical_path_is_heavy_branch():
    g, targets = fanout_graph()
    plan = plan_run(g, make_factory(), targets)
    assert plan.choice("b0", "__all__").critical
    for i in range(1, 5):
        ch = plan.choice(f"b{i}", "__all__")
        assert not ch.critical
        assert ch.slack_s > 0.0
    # src and sink bound every path, so they are critical too
    assert plan.choice("src", "__all__").critical
    assert plan.choice("sink", "__all__").critical


def test_partitioned_tasks_are_planned_per_partition():
    parts = StaticPartitions(("p0", "p1"))
    shards = _spec("shards", 50.0, parts=parts)
    merged = _spec("merged", 10.0, deps=("shards",), cls="light")
    plan = plan_run(AssetGraph([shards, merged]), make_factory(), ["merged"])
    assert set(plan.choices) == {("shards", "p0"), ("shards", "p1"),
                                 ("merged", "__all__")}


def test_platform_hint_is_pinned():
    a = _spec("a", 50.0, hint="pod-premium")
    plan = plan_run(AssetGraph([a]), make_factory(Objective.min_cost()))
    assert plan.choice("a", "__all__").platform == "pod-premium"


# ----------------------------------------------------------- constraints
def test_budget_infeasible_plan():
    g, targets = fanout_graph()
    obj = Objective.min_cost().constrained(budget_usd=0.01)
    plan = plan_run(g, make_factory(obj), targets)
    assert not plan.feasible
    assert "budget" in plan.reason
    # the coordinator refuses to execute a plan that is proven infeasible
    coord = RunCoordinator(g, nofail_factory(obj), use_cache=False)
    with pytest.raises(ValueError, match="infeasible"):
        coord.materialize(targets, plan=plan)


def test_deadline_infeasible_plan():
    g, targets = fanout_graph()
    obj = Objective.min_time().constrained(deadline_s=60.0)
    plan = plan_run(g, make_factory(obj), targets)
    assert not plan.feasible
    assert "deadline" in plan.reason


def test_deadline_buys_speed_on_critical_path_only():
    """min_cost alone picks cheap platforms; with a deadline the planner
    must upgrade the critical path while leaving slack tasks cheap."""
    g, targets = fanout_graph()
    free = plan_run(g, make_factory(Objective.min_cost()), targets)
    deadline = free.predicted_makespan_s * 0.8
    obj = Objective.min_cost().constrained(deadline_s=deadline)
    plan = plan_run(g, make_factory(obj), targets)
    assert plan.feasible
    assert plan.predicted_makespan_s <= deadline * (1 + 1e-9)
    assert plan.predicted_cost_usd >= free.predicted_cost_usd - 1e-9


def test_budget_feasible_plan_respects_budget():
    g, targets = fanout_graph()
    base = plan_run(g, make_factory(Objective.min_cost()), targets)
    obj = Objective.min_cost().constrained(
        budget_usd=base.predicted_cost_usd * 1.5)
    plan = plan_run(g, make_factory(obj), targets)
    assert plan.feasible
    assert plan.predicted_cost_usd <= obj.budget_usd


# ------------------------------------------------------------- dominance
def test_planned_dominates_greedy_predicted():
    g, targets = fanout_graph()
    plan = plan_run(g, make_factory(), targets)
    assert plan.predicted_cost_usd <= plan.greedy_cost_usd + 1e-9
    assert plan.predicted_makespan_s <= plan.greedy_makespan_s + 1e-9
    # the fan-out shape has real slack, so the planner must find savings
    assert plan.predicted_cost_usd < plan.greedy_cost_usd


def test_e2e_planned_run_cost_leq_greedy():
    """Fan-out/fan-in executed through the coordinator with deterministic
    simulated clients: the planned run must not cost more than greedy."""
    g, targets = fanout_graph()
    obj = Objective.balanced(600.0)

    coord_g = RunCoordinator(g, nofail_factory(obj), use_cache=False)
    greedy_rep = coord_g.materialize(targets, run_id="e2e-fixed")
    assert greedy_rep.ok

    coord_p = RunCoordinator(g, nofail_factory(obj), use_cache=False)
    plan = coord_p.plan(targets)
    planned_rep = coord_p.materialize(targets, run_id="e2e-fixed", plan=plan)
    assert planned_rep.ok
    assert planned_rep.total_cost <= greedy_rep.total_cost + 1e-6
    # every task ran on exactly the planned platform (no failures injected)
    for rec in planned_rep.records:
        assert rec.platform == plan.choice(rec.asset, rec.partition).platform


def test_plan_table_lists_every_task_and_totals():
    g, targets = fanout_graph()
    plan = plan_run(g, make_factory(), targets)
    table = plan.table()
    for (a, p) in plan.choices:
        assert f"{a}[{p}]" in table
    assert "planned:" in table and "greedy:" in table


# -------------------------------------------------- slot-aware agreement
class _NoJitterClient(SimulatedClusterClient):
    """Deterministic durations: the lognormal jitter draw is pinned to 1.0
    so recorded sim durations equal the cost-model estimates exactly."""

    class _Rng:
        @staticmethod
        def normal(*a, **kw):
            return 0.0

        @staticmethod
        def uniform(*a, **kw):
            return 0.999

    def _rng(self, ctx):
        return self._Rng()


def contended_fanout(width=24, work=60.0):
    """Far more parallel branches than slots: contention decides makespan."""
    specs = [_spec("src", 2.0)]
    for i in range(width):
        specs.append(_spec(f"b{i:02d}", work, deps=("src",)))
    specs.append(_spec("sink", 2.0, cls="light",
                       deps=tuple(f"b{i:02d}" for i in range(width))))
    return AssetGraph(specs), ["sink"]


def test_planner_makespan_within_5pct_of_coordinator_simulated():
    """Acceptance: planner and coordinator consume the same SlotConfig, and
    the planner's slot-aware predicted makespan lands within 5% of the
    makespan the coordinator's execution actually realizes (attempt
    durations + platforms replayed through the shared slot model)."""
    from repro.core import SlotConfig

    g, targets = contended_fanout()
    factory = DynamicClientFactory(
        default_catalog(), CostModel(), Objective.balanced(600.0),
        client_builder=lambda p: _NoJitterClient(
            p, failure_rate=0.0, preemption_rate=0.0))
    slots = SlotConfig(max_concurrent=8, platform_slots=2,
                       elastic_max_slots=8)
    coord = RunCoordinator(g, factory, slots=slots,
                           enable_speculation=False, use_cache=False)
    plan = coord.plan(targets)
    # the DAG really contends: some platform saturates its slot budget
    assert any(peak >= slots.capacity(name)
               for name, peak in plan.platform_peaks.items())
    report = coord.materialize(targets, run_id="slot-agree", plan=plan)
    assert report.ok
    actual = report.slot_makespan_s(coord.slots)
    assert actual > 0
    assert abs(plan.predicted_makespan_s - actual) <= 0.05 * actual
    # and the infinite-width view provably underestimates under contention —
    # the gap the slot-aware engine exists to close
    assert report.slot_makespan_s(None) < actual


def test_slot_prediction_exceeds_infinite_width_bound():
    from repro.core import SlotConfig

    g, targets = contended_fanout()
    plan = plan_run(g, make_factory(), targets)
    assert plan.predicted_makespan_s >= plan.pert_makespan_s * 2.0
    wide = plan_run(g, make_factory(), targets,
                    slots=SlotConfig(max_concurrent=64,
                                     elastic_max_slots=64))
    assert wide.predicted_makespan_s <= plan.predicted_makespan_s + 1e-9


# ---------------------------------------------------- coordinator fallback
def test_planned_run_falls_back_to_greedy_on_failover():
    """If the planned platform keeps failing, failover deny-lists it and the
    factory's greedy choose takes over — the run must still succeed."""
    retry = RetryPolicy(max_attempts=6, backoff_s=0.0, failover_after=2)
    a = _spec("solo", 50.0, retry=retry)
    g = AssetGraph([a])
    factory = DynamicClientFactory(
        default_catalog(), CostModel(), Objective.min_cost(),
        client_builder=lambda p: SimulatedClusterClient(
            p, seed=0,
            failure_rate=1.0 if p.name == "pod-spot" else 0.0,
            preemption_rate=0.0))
    reader = MessageReader()
    coord = RunCoordinator(g, factory, reader=reader, use_cache=False)
    plan = coord.plan(["solo"])
    assert plan.choice("solo", "__all__").platform == "pod-spot"
    report = coord.materialize(["solo"], plan=plan)
    assert report.ok
    rec = report.records[0]
    assert rec.attempts[0].platform == "pod-spot"
    assert rec.attempts[-1].platform != "pod-spot"
    assert reader.events(kind="FAILOVER")


# ------------------------------------------------- cache-aware planning
def test_warm_plan_prices_cached_and_agrees_with_coordinator():
    """Plan/coordinator agreement extended to the cached case: on a warm
    store the planner prices every task at $0 / 0s on the pseudo-platform
    'cached' with no platform slots, and the coordinator's warm run
    realizes exactly that — zero executed tasks, zero cost, zero
    slot-replayed makespan."""
    from repro.core import MaterializationStore, SlotConfig

    g, targets = contended_fanout(width=8, work=20.0)
    factory = DynamicClientFactory(
        default_catalog(), CostModel(), Objective.balanced(600.0),
        client_builder=lambda p: _NoJitterClient(
            p, failure_rate=0.0, preemption_rate=0.0))
    slots = SlotConfig(max_concurrent=8, platform_slots=2,
                       elastic_max_slots=8)
    coord = RunCoordinator(g, factory, store=MaterializationStore(),
                           slots=slots, enable_speculation=False)
    cold_plan = coord.plan(targets)
    assert cold_plan.cached_tasks == 0
    assert coord.materialize(targets, plan=cold_plan).ok

    warm_plan = coord.plan(targets)
    assert warm_plan.cached_tasks == len(warm_plan.choices) == \
        len(cold_plan.choices)
    assert warm_plan.stale_tasks == 0
    for c in warm_plan.choices.values():
        assert c.platform == "cached"
        assert c.expected_cost_usd == 0.0
        assert c.estimate.total_usd == 0.0 and c.estimate.duration_s == 0.0
    assert warm_plan.predicted_cost_usd == 0.0
    assert warm_plan.predicted_makespan_s == 0.0
    # cached tasks never occupy platform slots
    assert not any(warm_plan.platform_peaks.values())
    assert "cached:" in warm_plan.table()

    report = coord.materialize(targets, plan=warm_plan)
    assert report.ok and all(r.cached for r in report.records)
    assert report.total_cost == 0.0
    assert report.slot_makespan_s(coord.slots) == \
        warm_plan.predicted_makespan_s == 0.0


def test_partially_warm_plan_collapses_to_stale_cone():
    """Invalidating one branch leaves a stale cone of {branch, sink}: only
    those are priced on real platforms; execution stays inside the cone."""
    from repro.core import MaterializationStore, SlotConfig

    g, targets = contended_fanout(width=6, work=20.0)
    store = MaterializationStore()
    coord = RunCoordinator(g, nofail_factory(), store=store,
                           slots=SlotConfig(), enable_speculation=False)
    assert coord.materialize(targets).ok

    store.invalidate("b00")
    plan = coord.plan(targets)
    stale = {k for k, c in plan.choices.items() if c.platform != "cached"}
    assert stale == {("b00", "__all__"), ("sink", "__all__")}
    assert plan.cached_tasks == len(plan.choices) - 2
    assert plan.predicted_cost_usd <= 0.5 * coord.plan(
        targets, force=True).predicted_cost_usd

    report = coord.materialize(targets, plan=plan)
    executed = {(r.asset, r.partition) for r in report.records
                if not r.cached}
    # pessimistic plan prices the whole cone; early cutoff may shrink the
    # realized set further (b00 reproduces identical bytes -> sink cached)
    assert executed <= stale and ("b00", "__all__") in executed


def test_plan_accepts_selection_expressions():
    """plan()/materialize() take AssetSelection / string / legacy list and
    agree on the resulting task set."""
    from repro.core import AssetSelection

    g, _targets = fanout_graph(width=3)
    vals = [set(plan_run(g, make_factory(), spelling).choices)
            for spelling in (["sink"], "sink", "+sink",
                             AssetSelection.assets("sink").upstream())]
    assert all(v == vals[0] for v in vals)
    # selecting mid-graph assets still plans their required ancestors
    mid = set(plan_run(g, make_factory(), ["b0"]).choices)
    assert ("src", "__all__") in mid and ("sink", "__all__") not in mid
