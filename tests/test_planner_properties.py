"""Hypothesis property tests for the planner + scheduling engine.

Skipped as a module (not a collection error) when the ``hypothesis`` dev
extra is not installed, mirroring tests/test_properties.py.

Properties:

* dominance — over random DAGs, whenever the planner's slot-aware makespan
  is <= greedy's, its cost is <= greedy's too (the planner contract);
* slot monotonicity — on fan-out-structured DAGs (independent branches
  between chokepoints) the slot-aware makespan is monotone non-increasing
  as slot width grows.  (On arbitrary precedence graphs greedy list
  scheduling admits Graham anomalies, so full generality gets the provable
  (2 - 1/m) Graham envelope instead.)
* incremental retiming equals full recomputation on random DAGs.
"""
import string

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra not installed")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (AssetGraph, ComputeProfile, CostModel,  # noqa: E402
                        DynamicClientFactory, Objective, RunPlanner,
                        ScheduleEngine, SlotConfig, asset, default_catalog)

names = st.lists(st.text(alphabet=string.ascii_lowercase, min_size=1,
                         max_size=5), min_size=1, max_size=9, unique=True)
works = st.floats(1.0, 500.0)


def _factory(tv=600.0):
    return DynamicClientFactory(default_catalog(), CostModel(),
                                Objective.balanced(tv))


def _random_graph(ns, data):
    specs = []
    for i, n in enumerate(ns):
        possible = ns[:i]
        deps = tuple(data.draw(st.lists(st.sampled_from(possible),
                                        max_size=min(3, len(possible)),
                                        unique=True))) if possible else ()
        specs.append(asset(
            name=n, deps=deps,
            compute=ComputeProfile(
                work_chip_hours=data.draw(works),
                speedup_class=data.draw(
                    st.sampled_from(["scan", "shuffle", "light"])),
                min_chips=8))(lambda ctx, **kw: None))
    return AssetGraph(specs)


@given(names, st.data())
@settings(max_examples=25, deadline=None)
def test_plan_cost_leq_greedy_when_makespan_leq_greedy(ns, data):
    g = _random_graph(ns, data)
    plan = RunPlanner(g, _factory(), slots=SlotConfig()).plan()
    assert plan.feasible
    if plan.predicted_makespan_s <= plan.greedy_makespan_s * (1 + 1e-9):
        assert plan.predicted_cost_usd <= plan.greedy_cost_usd * (1 + 1e-9)
    # and with no deadline the planner must always stay in the envelope
    assert plan.predicted_makespan_s <= plan.greedy_makespan_s * (1 + 1e-9)


@given(st.integers(2, 24), st.data())
@settings(max_examples=25, deadline=None)
def test_slot_makespan_monotone_in_width_on_fanout(width, data):
    durs = [data.draw(st.floats(0.1, 10.0)) for _ in range(width)]
    keys = [("src", "__all__")] + \
        [(f"b{i:03d}", "__all__") for i in range(width)] + \
        [("sink", "__all__")]
    preds = {("src", "__all__"): []}
    for i in range(width):
        preds[(f"b{i:03d}", "__all__")] = [("src", "__all__")]
    preds[("sink", "__all__")] = [(f"b{i:03d}", "__all__")
                                  for i in range(width)]
    all_durs = [1.0] + durs + [1.0]
    prev = None
    for w in (1, 2, 4, 8, 32):
        e = ScheduleEngine(keys, preds,
                           SlotConfig(max_concurrent=64,
                                      elastic_max_slots=w))
        e.load(list(all_durs), ["p"] * len(keys))
        ms = e.slot_schedule().makespan_s
        if prev is not None:
            assert ms <= prev + 1e-9
        prev = ms


@given(names, st.data())
@settings(max_examples=20, deadline=None)
def test_slot_makespan_graham_envelope_on_random_dags(ns, data):
    """For arbitrary precedence, growing width from m1 to m2 >= m1 keeps the
    list-scheduled makespan within the provable Graham factor (2 - 1/m2) of
    the narrower schedule (anomalies exist, unbounded regressions do not)."""
    g = _random_graph(ns, data)
    from repro.core import task_dag
    keys, preds = task_dag(g, None)
    durs = [data.draw(st.floats(0.1, 10.0)) for _ in keys]
    ms = {}
    for w in (2, 4, 8):
        e = ScheduleEngine(keys, preds,
                           SlotConfig(max_concurrent=64,
                                      elastic_max_slots=w))
        e.load(list(durs), ["p"] * len(keys))
        ms[w] = e.slot_schedule().makespan_s
    assert ms[4] <= ms[2] * (2 - 1 / 4) + 1e-9
    assert ms[8] <= ms[4] * (2 - 1 / 8) + 1e-9


@given(names, st.data())
@settings(max_examples=20, deadline=None)
def test_incremental_retime_equals_full_pass(ns, data):
    g = _random_graph(ns, data)
    from repro.core import task_dag
    keys, preds = task_dag(g, None)
    durs = [data.draw(st.floats(0.1, 10.0)) for _ in keys]
    e = ScheduleEngine(keys, preds)
    e.load(list(durs))
    for _ in range(5):
        i = data.draw(st.integers(0, len(keys) - 1))
        durs[i] = data.draw(st.floats(0.1, 10.0))
        e.set_duration(i, durs[i])
        ref = ScheduleEngine(keys, preds)
        ref.load(list(durs))
        assert e.makespan_s == pytest.approx(ref.makespan_s)
        assert np.allclose(e.slack(), ref.slack())
