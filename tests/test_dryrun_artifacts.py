"""Schema + invariants of the dry-run artifacts (runs against whatever is in
artifacts/dryrun; skips cleanly if the sweep hasn't been run)."""
import glob
import json
import os

import pytest

ART = "artifacts/dryrun"

cells = [json.load(open(p)) for p in sorted(glob.glob(os.path.join(ART, "*.json")))]

pytestmark = pytest.mark.skipif(len(cells) < 10,
                                reason="dry-run artifacts not generated")


def test_cell_count_and_statuses():
    # 10 archs x 4 shapes x 2 meshes = 80 records
    assert len(cells) == 80
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    errors = [c for c in cells if c["status"] == "error"]
    assert not errors, [(c["arch"], c["shape"], c["error"]) for c in errors]
    assert len(ok) == 66
    assert len(skipped) == 14  # 7 full-attention archs x long_500k x 2 meshes


def test_skips_are_only_long_500k_full_attention():
    for c in cells:
        if c["status"] == "skipped":
            assert c["shape"] == "long_500k"
            assert "full-attention" in c["reason"]


def test_ok_cells_have_roofline_terms():
    for c in cells:
        if c["status"] != "ok":
            continue
        r = c["roofline"]
        for k in ("t_compute_s", "t_memory_s", "t_collective_s"):
            assert r[k] >= 0, (c["arch"], c["shape"], k)
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert r["step_time_s"] == max(r["t_compute_s"], r["t_memory_s"],
                                       r["t_collective_s"])
        assert c["model_flops"] > 0
        assert c["params_total"] >= c["params_active"] > 0


def test_memory_fits_hbm():
    """Argument residency (exact on CPU) must fit 16 GiB/chip."""
    for c in cells:
        if c["status"] != "ok":
            continue
        args = c["memory_analysis"].get("argument_size_in_bytes")
        if args is not None:
            assert args <= 16 * 2**30, (c["arch"], c["shape"], args / 2**30)


def test_multipod_shards_the_pod_axis():
    """The 2x16x16 mesh must reduce per-device argument bytes for train
    cells (DP over the pod axis halves the batch shard; weights unchanged)."""
    by = {(c["arch"], c["shape"], c["mesh"]): c for c in cells}
    for (arch, shape, mesh), c in by.items():
        if mesh != "16x16" or c["status"] != "ok" or c["kind"] != "train":
            continue
        multi = by.get((arch, shape, "2x16x16"))
        assert multi is not None and multi["status"] == "ok"
        a1 = c["memory_analysis"].get("argument_size_in_bytes", 0)
        a2 = multi["memory_analysis"].get("argument_size_in_bytes", 0)
        assert a2 <= a1 + 1e6, (arch, shape, a1, a2)


def test_params_match_analytic_count():
    """params_total in artifacts == ModelConfig.param_count() (stable)."""
    from repro.configs import get_config
    seen = set()
    for c in cells:
        if c["status"] != "ok" or c["arch"] in seen:
            continue
        seen.add(c["arch"])
        assert c["params_total"] == get_config(c["arch"]).param_count()


def test_moe_active_params_below_total():
    for c in cells:
        if c["status"] == "ok" and c["arch"].startswith("deepseek-v2"):
            assert c["params_active"] < 0.15 * c["params_total"]