"""Sanity-check the analytic FLOPs model (launch/flops.py) against XLA's own
cost analysis on a config with no scanned layers (1 layer, unrolled, no
remat) — the only regime where the HLO count isn't loop-body-undercounted."""
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch import flops as flops_mod
from repro.launch.analysis import safe_cost_analysis
from repro.launch.dryrun import make_train_step
from repro.models import LanguageModel
from repro.optim import AdamW, OptConfig


def _tiny_cfg():
    return ModelConfig(
        name="tiny", family="dense", source="test", n_layers=1, d_model=128,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=512,
        remat="none", compute_dtype="float32", pos_type="rope",
    )


def test_analytic_flops_within_band_of_hlo():
    cfg = _tiny_cfg()
    shape = ShapeSpec("t", "train", 128, 2)
    model = LanguageModel(cfg)
    opt = AdamW(OptConfig())
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    osd = jax.eval_shape(opt.init, params)
    batch = {
        "tokens": jax.ShapeDtypeStruct((2, 128), jnp.int32),
        "targets": jax.ShapeDtypeStruct((2, 128), jnp.int32),
        "weights": jax.ShapeDtypeStruct((2, 128), jnp.float32),
    }
    compiled = jax.jit(make_train_step(model, opt)).lower(
        params, osd, batch).compile()
    # jaxlib returns a dict or a one-element list depending on version —
    # safe_cost_analysis normalizes both (same helper the dry-run uses)
    hlo_flops = safe_cost_analysis(compiled).get("flops", 0.0)
    analytic = flops_mod.step_flops(cfg, shape)
    assert hlo_flops > 0
    # analytic assumes causal-efficient attention (S/2) and skips elementwise
    # flops; the XLA count includes the full quadratic + pointwise ops.
    ratio = hlo_flops / analytic
    assert 0.4 < ratio < 2.5, (hlo_flops, analytic, ratio)


def test_step_flops_scales_linearly_in_tokens():
    cfg = _tiny_cfg()
    f1 = flops_mod.step_flops(cfg, ShapeSpec("a", "train", 128, 2))
    f2 = flops_mod.step_flops(cfg, ShapeSpec("b", "train", 128, 4))
    assert abs(f2 / f1 - 2.0) < 0.05


def test_decode_flops_much_smaller_than_prefill():
    cfg = _tiny_cfg()
    fp = flops_mod.step_flops(cfg, ShapeSpec("p", "prefill", 4096, 8))
    fd = flops_mod.step_flops(cfg, ShapeSpec("d", "decode", 4096, 8))
    assert fd < fp / 100


def test_hbm_decode_dominated_by_weights_and_cache():
    cfg = _tiny_cfg()
    b = flops_mod.step_hbm_bytes(cfg, ShapeSpec("d", "decode", 32768, 128),
                                 n_chips=256, tp=16)
    weights = cfg.param_count() * 2 / 16
    assert b > weights  # cache term adds on top
