"""Optimizer + checkpoint behaviour: convergence, clipping, schedule,
save/restore roundtrip, auto-resume equivalence, async integrity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.optim import AdamW, OptConfig, cosine_schedule


def test_adamw_converges_on_quadratic():
    opt = AdamW(OptConfig(peak_lr=0.1, warmup_steps=5, decay_steps=200,
                          weight_decay=0.0, grad_clip=10.0))
    target = {"w": jnp.asarray([3.0, -2.0, 0.5]), "b": jnp.asarray(1.5)}
    params = {"w": jnp.zeros(3), "b": jnp.zeros(())}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        loss_fn = lambda p: (jnp.sum((p["w"] - target["w"]) ** 2)
                             + (p["b"] - target["b"]) ** 2)
        grads = jax.grad(loss_fn)(params)
        return opt.update(grads, state, params)

    for _ in range(150):
        params, state, stats = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(target["w"]), atol=1e-2)


def test_grad_clipping_bounds_update():
    opt = AdamW(OptConfig(peak_lr=1.0, warmup_steps=0, decay_steps=10,
                          grad_clip=1.0, weight_decay=0.0))
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, stats = opt.update(grads, state, params)
    assert float(stats["grad_norm"]) > 1e5  # pre-clip norm reported


def test_cosine_schedule_shape():
    cfg = OptConfig(peak_lr=1.0, min_lr_ratio=0.1, warmup_steps=10,
                    decay_steps=100)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100, 200)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1.0) < 1e-6
    assert 0.1 <= lrs[3] <= 1.0
    assert abs(lrs[4] - 0.1) < 1e-6
    assert abs(lrs[5] - 0.1) < 1e-6  # clamped past decay end


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nest": {"b": jnp.asarray([1, 2, 3], jnp.int32)}}
    mgr.save(10, tree, metadata={"note": "x"})
    assert mgr.latest_step() == 10
    restored = mgr.restore(10, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["nest"]["b"]),
                                  np.asarray(tree["nest"]["b"]))
    assert mgr.metadata(10)["note"] == "x"


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async_waits(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    tree = {"a": jnp.ones(128)}
    mgr.save(5, tree)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    tree = {"a": jnp.ones(4)}
    mgr.save(1, tree)
    # fake a torn write: step dir without COMMITTED marker
    os.makedirs(tmp_path / "step_00000002")
    assert mgr.latest_step() == 1


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    mgr.save(1, {"a": jnp.ones(4)})
    with pytest.raises(ValueError, match="shape"):
        mgr.restore(1, {"a": jnp.ones(5)})
