"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement).  Full configs are exercised only via the dry-run."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_configs
from repro.models import LanguageModel

ARCHS = [
    "whisper-medium", "h2o-danube-1.8b", "gemma-2b", "minicpm3-4b",
    "deepseek-7b", "recurrentgemma-9b", "deepseek-v2-236b",
    "granite-moe-1b-a400m", "qwen2-vl-72b", "rwkv6-1.6b",
]

GRAD_ARCHS = ["gemma-2b", "deepseek-v2-236b", "recurrentgemma-9b",
              "rwkv6-1.6b", "whisper-medium"]


def _mod(arch: str):
    return importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))


def smoke_config(arch: str):
    return _mod(arch).smoke()


def make_batch(cfg, batch=2, seq=32, seed=0):
    rng = np.random.RandomState(seed)
    b = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        "weights": jnp.ones((batch, seq), jnp.float32),
    }
    if cfg.enc_dec:
        b["frames"] = jnp.asarray(rng.randn(batch, seq, cfg.d_model), jnp.float32)
    return b


def test_all_archs_registered():
    assert sorted(ARCHS) == list_configs()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert np.isfinite(float(metrics["loss"]))
    # loss should start near uniform: log(vocab) within a wide band
    assert float(metrics["loss"]) < np.log(cfg.vocab_size) + 3.0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = smoke_config(arch)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    cache = model.init_cache(B, max_len=S + 4, enc_len=S)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), S, jnp.int32)
    logits2, cache = jax.jit(model.decode_step)(params, tok, cache, pos)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


@pytest.mark.parametrize("arch", GRAD_ARCHS)
def test_smoke_grads_finite(arch):
    cfg = smoke_config(arch)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg)

    @jax.jit
    def gradfn(p):
        return jax.grad(lambda p_: model.train_loss(p_, batch)[0])(p)

    grads = gradfn(params)
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert np.all(np.isfinite(np.asarray(g, np.float32)))
    # at least some gradient signal
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in flat]
    assert max(norms) > 0.0
