"""Crash-recovery benchmark: kill/resume economics + journaling overhead on
the Common-Crawl pipeline.

Two phases:

* **overhead** — the happy path run twice per repeat (journal off vs on,
  fresh store/journal dirs each time, ``SIM_TIME_SCALE`` so wall-clock
  reflects the DAG's real shape), min-of-repeats per arm.  The write-ahead
  journal fsyncs every record, so this measures the real durability tax;
  the CI gate requires it under the baseline's ``max_overhead_frac`` (5%).
* **kill/resume** — the coordinator is killed at ~25/50/75% of the
  journal's record stream (seeded ``FaultPlan`` record-boundary kill: the
  record is durable, the action may not be), then resumed with a fresh
  coordinator.  Executed in pure-accounting mode (``sim_time_scale=0``) so
  the deterministic clients make an uninterrupted run of the same run_id an
  exact reference.  Per kill point we check: resume completes, zero
  duplicate billing (journal idempotency keys), spend equal to the
  uninterrupted run, byte-identical store contents, and rework (re-launched
  previously-launched tasks) bounded by the crash frontier — plus report
  the rework fraction (re-executed / total tasks), the headline number for
  "how much work does a crash at X% cost us?".

Writes ``BENCH_recovery.json`` (or ``BENCH_recovery_smoke.json`` with
``--smoke``); CI's bench-smoke job gates via
``check_recovery_regression.py`` against
``benchmarks/baselines/recovery_baseline.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

# make `python benchmarks/recovery_bench.py` == `python -m benchmarks...`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from repro.core import (CoordinatorKilled, CostModel,  # noqa: E402
                        DynamicClientFactory, FaultPlan, JournalState,
                        MaterializationStore, MessageReader, MultiPartitions,
                        Objective, RunCoordinator, RunJournal,
                        StaticPartitions, default_catalog)
from benchmarks.cc_pipeline import build_graph  # noqa: E402

#: overhead arm: sleep = estimate.duration_s * scale; edges ~8.6h => ~3s, so
#: the base run is seconds-long and ~tens of fsync'd journal records cost a
#: small, measurable fraction of it
SIM_TIME_SCALE = 1e-4
KILL_FRACS = (0.25, 0.5, 0.75)


def _partitions(n_crawls: int, n_shards: int) -> MultiPartitions:
    crawls = tuple(f"2023-{10 + i:02d}" for i in range(n_crawls))
    shards = tuple(f"shard-{i}" for i in range(n_shards))
    return MultiPartitions(dims=(("time", StaticPartitions(crawls)),
                                 ("domain", StaticPartitions(shards))))


def _coordinator(graph, root: str, tag: str, journal: bool,
                 sim_time_scale: float, faults: FaultPlan | None = None,
                 seed: int = 0) -> RunCoordinator:
    factory = DynamicClientFactory(
        default_catalog(), CostModel(), Objective.balanced(),
        sim_seed=seed, sim_time_scale=sim_time_scale, faults=faults)
    return RunCoordinator(
        graph, factory, reader=MessageReader(),
        store=MaterializationStore(os.path.join(root, f"store-{tag}")),
        journal_dir=os.path.join(root, f"journal-{tag}") if journal else None,
        faults=faults)


# ------------------------------------------------------------------ overhead
def bench_overhead(graph, root: str, repeats: int) -> dict:
    times = {"plain": [], "journaled": []}
    records = 0
    for i in range(repeats):
        for arm, journal in (("plain", False), ("journaled", True)):
            tag = f"ovh-{arm}-{i}"
            coord = _coordinator(graph, root, tag, journal, SIM_TIME_SCALE)
            # same run_id for both arms: the simulated clients key durations
            # and outcomes on it, so the arms execute identical schedules
            t0 = time.perf_counter()
            report = coord.materialize(["graph_aggr"], run_id=f"ovh{i}")
            times[arm].append(time.perf_counter() - t0)
            assert report.ok
            if journal:
                recs, _ = RunJournal.load(
                    os.path.join(root, f"journal-{tag}"), f"ovh{i}")
                records = max(records, recs[-1]["seq"] + 1)
    plain, journaled = min(times["plain"]), min(times["journaled"])
    return {
        "repeats": repeats,
        "plain_s": round(plain, 4),
        "journaled_s": round(journaled, 4),
        "overhead_frac": round(max(journaled - plain, 0.0) / plain, 4),
        "journal_records": records,
    }


# --------------------------------------------------------------- kill/resume
def bench_kills(graph, root: str) -> tuple[dict, dict]:
    # probe: how many records does an uninterrupted journaled run write?
    probe = _coordinator(graph, root, "probe", True, 0.0)
    assert probe.materialize(["graph_aggr"], run_id="probe").ok
    n_records = RunJournal.load(
        os.path.join(root, "journal-probe"), "probe")[0][-1]["seq"] + 1

    kills: dict[str, dict] = {}
    checks: dict[str, bool] = {}
    for frac in KILL_FRACS:
        kill_at = max(2, int(n_records * frac))
        rid = f"kill{int(frac * 100)}"
        label = f"kill_{int(frac * 100)}"

        # uninterrupted reference with the SAME run_id (deterministic
        # clients key durations/outcomes on it)
        ref = _coordinator(graph, root, f"{label}-ref", True, 0.0)
        ref_report = ref.materialize(["graph_aggr"], run_id=rid)
        ref_keys = [(r.asset, r.partition) for r in ref_report.records]
        ref_hashes = {tk: ref.store.data_hash(*tk) for tk in ref_keys}
        ref_spend = JournalState.from_records(RunJournal.load(
            os.path.join(root, f"journal-{label}-ref"), rid)[0]).spent_usd()

        fp = FaultPlan(seed=1, kill_at_record=kill_at)
        chaos = _coordinator(graph, root, label, True, 0.0, faults=fp)
        killed = False
        try:
            chaos.materialize(["graph_aggr"], run_id=rid)
        except CoordinatorKilled:
            killed = True
        jdir = os.path.join(root, f"journal-{label}")
        pre = JournalState.from_records(RunJournal.load(jdir, rid)[0])
        frontier = pre.frontier()
        launched_before = set(pre.launches)

        resumer = _coordinator(graph, root, label, True, 0.0)
        t0 = time.perf_counter()
        resume_ok = True
        try:
            resume_ok = resumer.resume(rid).ok
        except ValueError:  # killed after END: already complete
            resume_ok = pre.ended and bool(pre.ok)
        resume_s = time.perf_counter() - t0

        post_recs, _ = RunJournal.load(jdir, rid)
        post = JournalState.from_records(post_recs)
        keys = post.billed_keys()
        got_hashes = {tk: resumer.store.data_hash(*tk) for tk in ref_keys}
        resume_seq = next((r["seq"] for r in post_recs
                           if r["kind"] == "RESUME"), None)
        relaunched = {(r["asset"], r["partition"]) for r in post_recs
                      if r["kind"] == "LAUNCH"
                      and resume_seq is not None and r["seq"] > resume_seq}
        rework = relaunched & launched_before

        kills[label] = {
            "kill_at_record": kill_at,
            "total_records": n_records,
            "killed": killed,
            "resume_s": round(resume_s, 4),
            "frontier_tasks": len(frontier),
            "relaunched_tasks": len(relaunched),
            "rework_tasks": len(rework),
            "total_tasks": len(ref_keys),
            "rework_fraction": round(len(rework) / len(ref_keys), 4),
            "spend_usd": round(post.spent_usd(), 6),
            "reference_spend_usd": round(ref_spend, 6),
        }
        checks[f"{label}_fired"] = killed or kill_at >= n_records
        checks[f"{label}_resume_ok"] = resume_ok
        checks[f"{label}_no_double_billing"] = len(keys) == len(set(keys))
        checks[f"{label}_spend_matches_reference"] = (
            abs(post.spent_usd() - ref_spend) < 1e-6)
        checks[f"{label}_store_identical"] = got_hashes == ref_hashes
        checks[f"{label}_rework_bounded_by_frontier"] = rework <= frontier
    return kills, checks


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small partition grid + fewer overhead repeats")
    ap.add_argument("--out", default=None,
                    help="default BENCH_recovery.json, or "
                         "BENCH_recovery_smoke.json with --smoke")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args()

    n_crawls, n_shards = (1, 2) if args.smoke else (2, 2)
    repeats = args.repeats or (2 if args.smoke else 3)
    out = args.out or ("BENCH_recovery_smoke.json" if args.smoke
                       else "BENCH_recovery.json")
    graph = build_graph(partitions=_partitions(n_crawls, n_shards))

    root = tempfile.mkdtemp(prefix="recovery-bench-")
    try:
        overhead = bench_overhead(graph, root, repeats)
        kills, checks = bench_kills(graph, root)
    finally:
        shutil.rmtree(root, ignore_errors=True)

    result = {
        "smoke": args.smoke,
        "partitions": {"crawls": n_crawls, "shards": n_shards},
        "overhead": overhead,
        "kills": kills,
        "checks": checks,
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(json.dumps(result, indent=1, sort_keys=True))
    print(f"\nwrote {out}: journaling overhead "
          f"{overhead['overhead_frac'] * 100:.1f}% "
          f"({overhead['journal_records']} records), "
          f"{sum(checks.values())}/{len(checks)} checks passed")


if __name__ == "__main__":
    main()
