"""Fig 5: total cost of production runs by asset, across multiple Common
Crawl batches (time x domain partitions), per platform policy."""
from __future__ import annotations

from benchmarks.cc_pipeline import run_policy
from repro.core import MultiPartitions, StaticPartitions

BATCHES = MultiPartitions(dims=(
    ("time", StaticPartitions(("2023-10", "2023-11", "2023-12"))),
    ("domain", StaticPartitions(("shard-0", "shard-1"))),
))


def run() -> dict:
    out = {}
    for policy in ("orchestrated", "all-spot", "all-premium"):
        report, reader = run_policy(policy, seed=7, partitions=BATCHES)
        out[policy] = {
            "cost_by_asset": {k: round(v, 2)
                              for k, v in report.by_asset_cost().items()},
            "total_cost": round(report.total_cost, 2),
            "makespan_h": round(report.makespan_s() / 3600.0, 2),
            "n_partitions": len(report.records) // 4,
        }
    # the paper's Fig-5 shape: edges dominates cost on every platform
    for policy in out:
        c = out[policy]["cost_by_asset"]
        assert c["edges"] > 10 * max(c["nodes"], c["graph_aggr"]), c
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
