"""CI gate: serving-throughput acceptance + regression vs committed baseline.

Usage (what .github/workflows/ci.yml runs after ``serving_bench.py --smoke``):

    python benchmarks/check_serving_regression.py \
        --current BENCH_serving_smoke.json \
        --baseline benchmarks/baselines/serving_baseline.json

Raw tokens/s is machine-dependent, so every gated metric is an *in-run
ratio* — both sides of each division come from the same sweep on the same
machine, so CPU speed cancels:

1. **Throughput acceptance** — ``summary.speedup_64`` (paged 64-slot tok/s
   over the seed dense 4-slot batcher on the identical trace) must be
   ``>= --min-speedup`` (default 3.0, the PR's acceptance bar).
2. **Prefill-interference bound** — the mixed-arrival run's p99 decode-tick
   wall must stay within ``--max-p99-ratio`` (default 2.0) of the
   no-prefill steady-state run's median tick wall: chunked prefill may not
   wreck tail decode latency.
3. **Host-sync economy** — the paged 64-slot run must sync the host at most
   once per ``--min-ticks-per-sync`` decode ticks (drain batching actually
   engaged; one sync per tick is the dense failure mode).
4. **Baseline drift** — ``speedup_64`` may not fall below
   ``--max-drift`` x the committed baseline's value.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_serving_smoke.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/serving_baseline.json")
    ap.add_argument("--min-speedup", type=float, default=3.0)
    ap.add_argument("--max-p99-ratio", type=float, default=2.0)
    ap.add_argument("--min-ticks-per-sync", type=float, default=4.0)
    ap.add_argument("--max-drift", type=float, default=0.6,
                    help="current speedup_64 must be >= this fraction of "
                         "the committed baseline's speedup_64")
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    failures: list[str] = []
    s = cur["summary"]

    speedup = s["speedup_64"]
    ok = speedup >= args.min_speedup
    print(f"paged-vs-dense speedup at 64 slots: {speedup:.2f}x "
          f"(min {args.min_speedup}x) {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(
            f"speedup_64 {speedup:.2f}x below acceptance bar "
            f"{args.min_speedup}x")

    p99r = s["p99_over_steady_p50"]
    ok = p99r <= args.max_p99_ratio
    print(f"mixed p99 tick / steady p50 tick:   {p99r:.2f}x "
          f"(max {args.max_p99_ratio}x) {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(
            f"prefill interference: p99 tick is {p99r:.2f}x the no-prefill "
            f"steady-state median (max {args.max_p99_ratio}x)")

    paged = cur["scenarios"]["paged_s64_mixed"]
    tps = paged["ticks"] / max(paged["host_syncs"], 1)
    ok = tps >= args.min_ticks_per_sync
    print(f"decode ticks per host sync (paged): {tps:.1f} "
          f"(min {args.min_ticks_per_sync}) {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(
            f"host-sync batching not engaged: {tps:.1f} ticks/sync "
            f"(min {args.min_ticks_per_sync})")

    b = base["summary"]["speedup_64"]
    drift = speedup / max(b, 1e-9)
    ok = drift >= args.max_drift
    print(f"speedup_64 vs committed baseline:   {drift:.2f}x of {b:.2f}x "
          f"(min {args.max_drift}x) {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(
            f"speedup_64 {speedup:.2f}x is only {drift:.2f}x of the "
            f"baseline {b:.2f}x (floor {args.max_drift}x)")

    rej = cur["scenarios"]["paged_s64_mixed"].get("rejected", 0)
    if rej:
        failures.append(
            f"paged_s64_mixed rejected {rej} requests — the 64-slot pool "
            f"must fit the benchmark trace")

    if failures:
        print("\nSERVING REGRESSION:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("serving gate: all checks passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
