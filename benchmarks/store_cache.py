"""Cross-run cache benchmark: cold vs warm vs single-partition backfill on
the Common-Crawl pipeline, against the content-addressed
``MaterializationStore``.

Four phases, each with a *fresh* store instance and coordinator on the same
store directory (so every phase exercises the persistent index, not
in-process state):

* **cold**  — empty store: every (asset, partition) task executes;
* **warm**  — nothing changed: the planner prices every task ``cached`` and
  the run executes **zero** tasks, so wall-clock collapses to bookkeeping
  (the gate requires >= 10x faster than cold);
* **backfill** — one ``nodes`` partition's source data changes (store record
  invalidated + a salt folded into the recomputed output): exactly that
  partition's downstream cone re-executes (4 of the 4 x P tasks), every
  other partition stays cached;
* **cutoff** — one ``nodes`` record invalidated with *unchanged* source
  data: ``nodes`` re-runs, reproduces byte-identical output, and the
  downstream cone is cut off — exactly **one** task executes even though
  the pessimistic upfront resolution marked the whole cone stale.

Execution sleeps ``estimate.duration_s * SIM_TIME_SCALE`` per task
(``SimulatedClusterClient``), so cold wall-clock reflects the DAG's real
shape (edges dominates) and the warm speedup is measured against genuine
concurrency, not a no-op loop.

Writes ``BENCH_store.json`` (or ``BENCH_store_smoke.json`` with ``--smoke``);
CI's bench-smoke job runs ``--smoke`` and ``check_store_regression.py``
gates on the booleans + the warm speedup floor in
``benchmarks/baselines/store_cache_baseline.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

# make `python benchmarks/store_cache.py` == `python -m benchmarks.store_cache`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from repro.core import (CostModel, DynamicClientFactory,  # noqa: E402
                        MaterializationStore, MessageReader, MultiPartitions,
                        Objective, RunCoordinator, SimulatedClusterClient,
                        StaticPartitions, default_catalog)
from benchmarks.cc_pipeline import build_graph  # noqa: E402

#: sleep = estimate.duration_s * scale; edges ~ 8.6 h => ~3 s per task, so a
#: cold run takes seconds while a warm run takes milliseconds — a >= 10x
#: speedup floor is robust even on a noisy CI runner
SIM_TIME_SCALE = 1e-4


def _partitions(n_crawls: int, n_shards: int) -> MultiPartitions:
    crawls = tuple(f"2023-{10 + i:02d}" for i in range(n_crawls))
    shards = tuple(f"shard-{i}" for i in range(n_shards))
    return MultiPartitions(dims=(("time", StaticPartitions(crawls)),
                                 ("domain", StaticPartitions(shards))))


def _coordinator(store_dir: str, parts: MultiPartitions,
                 salt: dict | None = None) -> tuple[RunCoordinator,
                                                    MessageReader]:
    graph = build_graph(partitions=parts, salt=salt)
    store = MaterializationStore(store_dir)  # fresh instance: disk is truth
    reader = MessageReader()
    factory = DynamicClientFactory(
        default_catalog(), CostModel(), Objective.balanced(),
        client_builder=lambda p: SimulatedClusterClient(
            p, failure_rate=0.0, preemption_rate=0.0,
            sim_time_scale=SIM_TIME_SCALE))
    coord = RunCoordinator(graph, factory, store=store, reader=reader,
                           enable_speculation=False)
    return coord, reader


def _phase(name: str, store_dir: str, parts: MultiPartitions,
           salt: dict | None = None) -> dict:
    coord, reader = _coordinator(store_dir, parts, salt=salt)
    t0 = time.perf_counter()
    plan = coord.plan("graph_aggr")
    report = coord.materialize("graph_aggr", run_id=f"store-bench-{name}",
                               plan=plan)
    wall_s = time.perf_counter() - t0
    executed = sorted((r.asset, r.partition) for r in report.records
                      if not r.cached)
    cached_platforms_scheduled = sorted(
        {c.platform for c in plan.choices.values()} - {"cached"})
    return {
        "wall_s": round(wall_s, 4),
        "tasks_total": len(report.records),
        "tasks_executed": len(executed),
        "executed": [f"{a}[{p}]" for a, p in executed],
        "plan_cached_tasks": plan.cached_tasks,
        "plan_stale_tasks": plan.stale_tasks,
        "plan_platforms_scheduled": cached_platforms_scheduled,
        "cache_stats": reader.cache_stats(f"store-bench-{name}"),
        "ok": report.ok,
    }


def run(n_crawls: int, n_shards: int, store_dir: str) -> dict:
    parts = _partitions(n_crawls, n_shards)
    pkeys = parts.keys()
    target_part = pkeys[0]
    n_parts = len(pkeys)

    cold = _phase("cold", store_dir, parts)
    warm = _phase("warm", store_dir, parts)

    # backfill: partition 0's crawl snapshot is refreshed — the store record
    # is dropped and the recomputed nodes output carries a salt token (new
    # upstream *data*, unchanged code), so exactly its downstream cone runs
    MaterializationStore(store_dir).invalidate("nodes", target_part)
    backfill = _phase("backfill", store_dir, parts,
                      salt={target_part: "refresh-1"})
    expected_cone = sorted(f"{a}[{target_part}]"
                           for a in ("nodes", "edges", "graph", "graph_aggr"))

    # early cutoff: drop the same record with *unchanged* inputs — nodes
    # re-runs, reproduces identical bytes, downstream cone stays cached
    MaterializationStore(store_dir).invalidate("nodes", target_part)
    cutoff = _phase("cutoff", store_dir, parts,
                    salt={target_part: "refresh-1"})

    speedup = cold["wall_s"] / max(warm["wall_s"], 1e-9)
    checks = {
        "cold_all_executed": cold["tasks_executed"] == cold["tasks_total"],
        "warm_zero_tasks": warm["tasks_executed"] == 0,
        "warm_10x_faster": speedup >= 10.0,
        "warm_plan_all_cached":
            warm["plan_cached_tasks"] == warm["tasks_total"],
        "warm_plan_no_slots": warm["plan_platforms_scheduled"] == [],
        "backfill_exact_cone": backfill["executed"] == expected_cone,
        "cutoff_single_task":
            cutoff["executed"] == [f"nodes[{target_part}]"],
        "all_runs_ok": all(p["ok"] for p in (cold, warm, backfill, cutoff)),
    }
    return {
        "config": {"n_crawls": n_crawls, "n_shards": n_shards,
                   "n_partitions": n_parts,
                   "n_tasks": cold["tasks_total"],
                   "sim_time_scale": SIM_TIME_SCALE,
                   "target_partition": target_part},
        "cold": cold, "warm": warm, "backfill": backfill, "cutoff": cutoff,
        "warm_speedup": round(speedup, 2),
        "checks": checks,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small partition grid for CI (8 tasks)")
    ap.add_argument("--out", default=None,
                    help="default BENCH_store.json, or BENCH_store_smoke.json "
                         "with --smoke so smoke runs never clobber the full "
                         "benchmark")
    ap.add_argument("--store-dir", default=None,
                    help="store directory (default: fresh temp dir)")
    args = ap.parse_args()

    n_crawls, n_shards = (1, 2) if args.smoke else (2, 2)
    out = args.out or ("BENCH_store_smoke.json" if args.smoke
                       else "BENCH_store.json")
    store_dir = args.store_dir or tempfile.mkdtemp(prefix="store_bench_")
    cleanup = args.store_dir is None
    try:
        result = run(n_crawls, n_shards, store_dir)
    finally:
        if cleanup:
            shutil.rmtree(store_dir, ignore_errors=True)

    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"cold {result['cold']['wall_s']:.2f}s "
          f"({result['cold']['tasks_executed']} tasks) | "
          f"warm {result['warm']['wall_s']:.3f}s "
          f"({result['warm']['tasks_executed']} tasks, "
          f"{result['warm_speedup']:.0f}x) | "
          f"backfill {result['backfill']['tasks_executed']} tasks | "
          f"cutoff {result['cutoff']['tasks_executed']} task")
    for name, ok in sorted(result["checks"].items()):
        print(f"  {'PASS' if ok else 'FAIL'} {name}")
    print(f"wrote {out}")
    if not all(result["checks"].values()):
        raise SystemExit("store cache benchmark checks failed")


if __name__ == "__main__":
    main()
