"""Fig 6: run-duration distributions per step and platform (premium's
optimized runtime consistently shortens the heavy steps)."""
from __future__ import annotations

import statistics

from benchmarks.cc_pipeline import SMALL, run_policy


def run(n_seeds: int = 10) -> dict:
    durs: dict[tuple[str, str], list[float]] = {}
    for seed in range(n_seeds):
        for policy, plat in (("all-spot", "pod-spot"),
                             ("all-premium", "pod-premium")):
            _, reader = run_policy(policy, seed=200 + seed, partitions=SMALL)
            for ev in reader.events(kind="SUCCESS"):
                durs.setdefault((ev.asset, plat), []).append(
                    ev.payload["duration_s"] / 3600.0)
    table = {}
    for (a, p), vals in sorted(durs.items()):
        table[f"{a}@{p}"] = {
            "median_h": round(statistics.median(vals), 3),
            "p90_h": round(sorted(vals)[int(0.9 * (len(vals) - 1))], 3),
            "n": len(vals),
        }
    # premium must be consistently faster on the heavy chip-capped step
    # (Fig 6): edges 8.7 h vs 5.9 h expected, robust against the 18% jitter.
    # Right-sized small assets absorb the Photon speedup into cluster size,
    # leaving only startup latency (0.98 vs 0.90 h expected) — inside jitter
    # noise at benchmark sample counts, so reported but not asserted.
    spot = table["edges@pod-spot"]["median_h"]
    prem = table["edges@pod-premium"]["median_h"]
    assert spot > 1.25 * prem, ("edges", spot, prem)
    return table


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
