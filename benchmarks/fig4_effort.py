"""Fig 4: cumulative platform-adaptation effort until production stability.

The paper: EMR needed ~2x the trial runs of DBR before stabilizing, each
failure prompting a configuration change (YARN node labels, memory doubling,
vacuum parallelism...).  Model: a learning curve — every failed trial run
triggers one config change that multiplicatively reduces the platform's
failure odds toward its steady-state rate (the catalog value); a platform is
"production stable" after K consecutive clean runs.  Cumulative changes vs
trial index reproduces Fig 4's shape, and the trial-count ratio its ~2x gap.
"""
from __future__ import annotations

import numpy as np

# initial failure odds reflect each platform's out-of-box experience
# (§6: EMR "labor-intensive and fraught with technical challenges")
INITIAL_FAIL = {"pod-spot": 0.60, "pod-premium": 0.30}
STEADY_FAIL = {"pod-spot": 0.30, "pod-premium": 0.12}  # Fig-3 rates
LEARN = 0.85  # each config change removes 15% of the excess failure odds
STABLE_AFTER = 5  # consecutive clean trial runs


def simulate(platform: str, seed: int) -> dict:
    rng = np.random.RandomState(seed)
    fail = INITIAL_FAIL[platform]
    steady = STEADY_FAIL[platform]
    changes, trials, streak = 0, 0, 0
    curve = [(0, 0)]
    while streak < STABLE_AFTER and trials < 400:
        trials += 1
        if rng.rand() < fail:
            streak = 0
            changes += 1  # a failure forces a config revision
            fail = steady + (fail - steady) * LEARN
        else:
            streak += 1
        curve.append((trials, changes))
    return {"trials": trials, "changes": changes, "curve": curve}


def run(n_seeds: int = 40) -> dict:
    out = {}
    for plat in INITIAL_FAIL:
        runs = [simulate(plat, 1000 + s) for s in range(n_seeds)]
        out[plat] = {
            "mean_trials": float(np.mean([r["trials"] for r in runs])),
            "mean_changes": float(np.mean([r["changes"] for r in runs])),
            "p90_trials": float(np.percentile([r["trials"] for r in runs],
                                              90)),
            "example_curve": runs[0]["curve"][-1],
        }
    ratio = out["pod-spot"]["mean_trials"] / out["pod-premium"]["mean_trials"]
    out["trial_ratio_spot_over_premium"] = float(ratio)
    # the paper's "almost double the number of trial runs for EMR"
    assert 1.5 < ratio < 3.0, ratio
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
