"""CI gate: compare a fresh BENCH_planner_scale.json against the committed
baseline and fail on plan-time regression.

Usage (what .github/workflows/ci.yml runs after ``planner_scale.py --smoke``):

    python benchmarks/check_planner_regression.py \
        --current BENCH_planner_scale.json \
        --baseline benchmarks/baselines/planner_scale_baseline.json \
        --size 1000 --max-ratio 1.5

Every DAG shape present in both files is checked at ``--size``.  Raw
wall-clock is machine-dependent (CI runners are slower than the machine
that recorded the baseline), so the gate compares the *normalized* plan
time — ``new.plan_time_s / legacy.plan_time_s`` — against the baseline's
normalized value: the legacy planner runs in the same process on the same
hardware, so machine speed cancels and only genuine planner regressions
move the ratio.  Sub-100ms cells still jitter (scheduler, GC), so a
regression additionally requires the raw plan time to exceed the baseline
by ``--min-delta-s``: the gate exists to catch the legacy planner's
quadratic blowup (~0.03s -> seconds at 1,000 tasks), not 40ms of noise.
The quality booleans (``cost_ok`` / ``makespan_ok``) from the current run
must all hold too — a fast planner shipping worse plans is still a
regression.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_planner_scale.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/planner_scale_baseline.json")
    ap.add_argument("--size", type=int, default=1000)
    ap.add_argument("--max-ratio", type=float, default=1.5)
    ap.add_argument("--min-delta-s", type=float, default=0.25,
                    help="absolute raw plan-time excess a regression must "
                         "also show (noise floor for sub-100ms cells)")
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    size = str(args.size)
    failures: list[str] = []
    checked = 0
    for shape, cells in sorted(base["shapes"].items()):
        if size not in cells or shape not in cur["shapes"] \
                or size not in cur["shapes"][shape]:
            continue
        b_cell = cells[size]
        c_cell = cur["shapes"][shape][size]
        b = b_cell["new"]["plan_time_s"] / max(
            b_cell["legacy"]["plan_time_s"], 1e-9)
        c = c_cell["new"]["plan_time_s"] / max(
            c_cell["legacy"]["plan_time_s"], 1e-9)
        ratio = c / max(b, 1e-9)
        raw_delta = (c_cell["new"]["plan_time_s"]
                     - b_cell["new"]["plan_time_s"])
        regressed = ratio > args.max_ratio and raw_delta > args.min_delta_s
        status = "REGRESSION" if regressed else "OK"
        print(f"{shape:>18} @ {size}: normalized plan time "
              f"baseline {b:.3f} -> current {c:.3f} ({ratio:.2f}x) {status} "
              f"[raw {c_cell['new']['plan_time_s']:.3f}s, "
              f"delta {raw_delta:+.3f}s]")
        checked += 1
        if regressed:
            failures.append(
                f"{shape}@{size}: normalized plan time {c:.3f} is "
                f"{ratio:.2f}x the baseline {b:.3f} (max {args.max_ratio}x) "
                f"and raw time grew {raw_delta:+.3f}s "
                f"(floor {args.min_delta_s}s)")
        for flag in ("cost_ok", "makespan_ok"):
            if flag in c_cell and not c_cell[flag]:
                failures.append(f"{shape}@{size}: {flag} is false — the "
                                f"plan regressed vs the legacy reference")
    if checked == 0:
        failures.append(f"no comparable cells at size {size} — baseline or "
                        f"current file malformed?")
    if failures:
        print("\n".join(["PLANNER BENCH REGRESSION:"] + failures),
              file=sys.stderr)
        return 1
    print(f"planner bench OK: {checked} shapes within "
          f"{args.max_ratio}x of baseline at {size} tasks")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
