"""CI gate: compare a fresh BENCH_recovery(_smoke).json against the
committed baseline and fail on crash-recovery regressions.

Usage (what .github/workflows/ci.yml runs after ``recovery_bench.py
--smoke``):

    python benchmarks/check_recovery_regression.py \
        --current BENCH_recovery_smoke.json \
        --baseline benchmarks/baselines/recovery_baseline.json

Two kinds of check:

* **correctness booleans** — every entry in the current run's ``checks``
  must hold: at each kill point (25/50/75% of the journal record stream)
  the kill fired, resume completed, no billing idempotency key appears
  twice, spend equals the uninterrupted same-run_id reference, the store
  is byte-identical to it, and rework is bounded by the crash frontier.
  These are machine-independent semantics; any failure is a regression
  outright.
* **journaling overhead ceiling** — ``overhead.overhead_frac`` (journaled
  vs plain happy-path wall-clock, min-of-repeats, identical simulated
  schedules) must stay under the baseline's ``max_overhead_frac``.  The
  ratio is self-normalizing across runners (both arms run in the same
  process on the same disk), and the ceiling (5%) sits far above the
  observed value (<1%), so only a genuine durability-path regression —
  extra fsyncs per record, journal writes off the happy path — can trip
  it.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_recovery_smoke.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/recovery_baseline.json")
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    failures: list[str] = []
    for name, ok in sorted(cur.get("checks", {}).items()):
        if not ok:
            failures.append(f"check failed: {name}")
    ceiling = base.get("max_overhead_frac", 0.05)
    frac = cur.get("overhead", {}).get("overhead_frac", 1.0)
    if frac > ceiling:
        failures.append(f"journaling overhead {frac * 100:.1f}% above the "
                        f"{ceiling * 100:.0f}% ceiling")

    print(f"recovery gate: journaling overhead {frac * 100:.1f}% "
          f"(ceiling {ceiling * 100:.0f}%), "
          f"{len(cur.get('checks', {}))} checks")
    if failures:
        for fmsg in failures:
            print(f"REGRESSION: {fmsg}", file=sys.stderr)
        return 1
    print("OK: no crash-recovery regression")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
