"""Pallas kernel tile sweep: autotune the flash-attention / WKV hot paths
over the model-config zoo and record tuned-vs-default speedups.

Twelve kernel workload configs cover every attention/recurrence variant the
registered model archs reach (``repro/configs``): bidirectional encoder
self-attention, causal decoder self-attention, cross-attention with
Sq != Skv, sliding-window GQA (danube3, recurrentgemma), MQA with 256-wide
heads (gemma), MLA with asymmetric qk/v head dims (minicpm3, deepseek-v2),
classic MHA (deepseek-7b), narrow-head GQA (granite, qwen2-vl), and the
RWKV-6 WKV linear scan.

For each config the sweep:

1. generates the validated tile-candidate set
   (``kernels.autotune.attention_candidates`` / ``scan_candidates``),
2. times every candidate through the tuner (``Autotuner.tune`` with
   ``force=True`` so a shipped cache never mixes another machine's numbers
   into this run), persisting the winner into the autotune cache,
3. reads the fixed-default tile's time out of the same sweep — the default
   is always a candidate here, so ``speedup = default_us / tuned_us >= 1.0``
   by construction,
4. derives the roofline fraction (achieved FLOP/s over the v5e peak from
   ``repro.core.platforms``) — meaningful on TPU, recorded-but-tiny in
   interpret mode; ``mode`` in the JSON says which one you are reading.

Writes ``BENCH_kernels.json``; CI's bench-smoke job re-runs a 4-config
subset (``--smoke``) at identical shapes and
``benchmarks/check_kernel_regression.py`` fails on a >1.5x regression of
the normalized tuned/default ratio vs the committed baseline.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

# make `python benchmarks/kernel_bench.py` == `python -m benchmarks.kernel_bench`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.platforms import PEAK_FLOPS  # noqa: E402
from repro.kernels import autotune as at  # noqa: E402
from repro.kernels import ops  # noqa: E402

#: the 12 kernel workload configs (11 attention variants + WKV).  Sequence
#: lengths are sized so the interpret-mode sweep stays in CI budget; head
#: dims / GQA ratios / masking flags — the tile-relevant structure — match
#: the registered model archs exactly.
ATTN_CONFIGS = [
    dict(name="whisper-medium-enc-self", B=1, Sq=512, Skv=512, Hq=2, Hkv=2,
         D=64, Dv=64, causal=False, window=0),
    dict(name="whisper-medium-dec-self", B=1, Sq=512, Skv=512, Hq=2, Hkv=2,
         D=64, Dv=64, causal=True, window=0),
    dict(name="whisper-medium-xattn", B=1, Sq=256, Skv=512, Hq=2, Hkv=2,
         D=64, Dv=64, causal=False, window=0),
    dict(name="danube3-500m-swa-gqa", B=1, Sq=512, Skv=512, Hq=2, Hkv=1,
         D=80, Dv=80, causal=True, window=256),
    dict(name="gemma-2b-mqa", B=1, Sq=512, Skv=512, Hq=2, Hkv=1,
         D=256, Dv=256, causal=True, window=0),
    dict(name="minicpm3-mla", B=1, Sq=512, Skv=512, Hq=2, Hkv=2,
         D=96, Dv=64, causal=True, window=0),
    dict(name="deepseek-7b-mha", B=1, Sq=512, Skv=512, Hq=2, Hkv=2,
         D=128, Dv=128, causal=True, window=0),
    dict(name="recurrentgemma-2b-swa-mqa", B=1, Sq=512, Skv=512, Hq=2, Hkv=1,
         D=256, Dv=256, causal=True, window=128),
    dict(name="deepseek-v2-lite-mla", B=1, Sq=512, Skv=512, Hq=2, Hkv=2,
         D=192, Dv=128, causal=True, window=0),
    dict(name="granite-moe-gqa", B=1, Sq=512, Skv=512, Hq=2, Hkv=1,
         D=64, Dv=64, causal=True, window=0),
    dict(name="qwen2-vl-2b-gqa", B=1, Sq=512, Skv=512, Hq=2, Hkv=1,
         D=128, Dv=128, causal=True, window=0),
]
WKV_CONFIGS = [
    dict(name="rwkv6-1b6-wkv", B=1, S=512, H=2, N=64),
]

#: CI subset: one config per kernel family at identical shapes, so the
#: regression gate's normalized ratios compare like with like
SMOKE_NAMES = ("whisper-medium-enc-self", "gemma-2b-mqa", "deepseek-7b-mha",
               "rwkv6-1b6-wkv")

DEFAULT_ATTN = {"block_q": ops.DEFAULT_BLOCK_Q, "block_k": ops.DEFAULT_BLOCK_K}
DEFAULT_SCAN = {"chunk": ops.DEFAULT_CHUNK}


def _attn_flops(c: dict) -> float:
    """QK^T + PV matmul FLOPs actually computed by the kernel (mask-aware:
    causal halves the score area, a window caps the k extent per query)."""
    Skv = c["Skv"]
    if c["window"]:
        pairs = c["Sq"] * min(c["window"] + 1, Skv)
    elif c["causal"]:
        pairs = c["Sq"] * (Skv - (c["Sq"] - 1) / 2.0)
    else:
        pairs = c["Sq"] * Skv
    return 2.0 * c["B"] * c["Hq"] * pairs * (c["D"] + c["Dv"])


def _wkv_flops(c: dict) -> float:
    """State update (k v^T + decay) plus readout (r . S) per step."""
    return 4.0 * c["B"] * c["S"] * c["H"] * c["N"] * c["N"]


def _cfg_key(cfg: dict) -> str:
    return json.dumps(cfg, sort_keys=True)


def bench_attention(c: dict, tuner: at.Autotuner, *, interpret: bool,
                    iters: int, warmup: int) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    dt = jnp.bfloat16
    q = jax.random.normal(ks[0], (c["B"], c["Sq"], c["Hq"], c["D"]), dt)
    k = jax.random.normal(ks[1], (c["B"], c["Skv"], c["Hkv"], c["D"]), dt)
    v = jax.random.normal(ks[2], (c["B"], c["Skv"], c["Hkv"], c["Dv"]), dt)
    q_offset = c["Skv"] - c["Sq"] if c["causal"] else 0

    def measure(cfg: dict) -> float:
        return at.measure_us(
            lambda: ops.flash_attention(
                q, k, v, causal=c["causal"], window=c["window"],
                q_offset=q_offset, block_q=cfg["block_q"],
                block_k=cfg["block_k"], interpret=interpret),
            iters=iters, warmup=warmup)

    key = at.attention_key(q.shape, k.shape, v.shape, dt, causal=c["causal"],
                           window=c["window"],
                           backend=at.backend_tag(interpret))
    cands = at.attention_candidates(c["Sq"], c["Skv"], c["D"], c["Dv"], dt)
    entry = tuner.tune(key, cands, measure, force=True,
                       mode="interpret" if interpret else "tpu")
    return _report(c, entry, DEFAULT_ATTN, measure, _attn_flops(c), key)


def bench_wkv(c: dict, tuner: at.Autotuner, *, interpret: bool,
              iters: int, warmup: int) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    shp = (c["B"], c["S"], c["H"], c["N"])
    r, k, v = (jax.random.normal(ks[i], shp, jnp.float32) for i in range(3))
    lw = -jnp.exp(jax.random.uniform(ks[3], shp, jnp.float32, -6.0, 0.0))
    u = jax.random.normal(ks[4], (c["H"], c["N"]), jnp.float32) * 0.1
    s0 = jnp.zeros((c["B"], c["H"], c["N"], c["N"]), jnp.float32)

    def measure(cfg: dict) -> float:
        return at.measure_us(
            lambda: ops.linear_scan(r, k, v, lw, u, s0, chunk=cfg["chunk"],
                                    interpret=interpret)[0],
            iters=iters, warmup=warmup)

    key = at.scan_key(shp, jnp.float32, backend=at.backend_tag(interpret))
    cands = at.scan_candidates(c["S"], c["N"], jnp.float32)
    entry = tuner.tune(key, cands, measure, force=True,
                       mode="interpret" if interpret else "tpu")
    return _report(c, entry, DEFAULT_SCAN, measure, _wkv_flops(c), key)


def _report(c: dict, entry: dict, default_cfg: dict, measure, flops: float,
            key: str) -> dict:
    """Per-config result row.  The default tile is normally in the timed
    candidate set (same sweep, same noise), so the tuned minimum can never
    lose to it; if divisibility ever excluded the default, time it now and
    still never report a winner slower than the default."""
    tuned_cfg, tuned_us = entry["config"], float(entry["us"])
    default_us = entry["candidates"].get(_cfg_key(default_cfg))
    if default_us is None:
        default_us = measure(default_cfg)
    default_us = float(default_us)
    if tuned_us > default_us:  # only reachable when default wasn't swept
        tuned_cfg, tuned_us = dict(default_cfg), default_us
    shape = {k: v for k, v in c.items() if k != "name"}
    return {
        "kind": "attention" if "block_q" in tuned_cfg else "wkv",
        "shape": shape,
        "cache_key": key,
        "n_candidates": len(entry["candidates"]),
        "tuned": tuned_cfg,
        "tuned_us": round(tuned_us, 2),
        "default": default_cfg,
        "default_us": round(default_us, 2),
        "speedup_vs_default": round(default_us / max(tuned_us, 1e-9), 3),
        "flops": flops,
        "roofline_frac": flops / (max(tuned_us, 1e-9) * 1e-6) / PEAK_FLOPS,
    }


def run(*, smoke: bool = False, iters: int = 3, warmup: int = 1) -> dict:
    interpret = jax.default_backend() != "tpu"
    tuner = at.get_tuner()
    out: dict = {
        "mode": "interpret" if interpret else "tpu",
        "backend": at.backend_tag(interpret),
        "peak_flops": PEAK_FLOPS,
        "configs": {},
    }
    sweep = [("attention", c) for c in ATTN_CONFIGS] + \
            [("wkv", c) for c in WKV_CONFIGS]
    if smoke:
        sweep = [(kind, c) for kind, c in sweep if c["name"] in SMOKE_NAMES]
    for kind, c in sweep:
        fn = bench_attention if kind == "attention" else bench_wkv
        row = fn(c, tuner, interpret=interpret, iters=iters, warmup=warmup)
        out["configs"][c["name"]] = row
        print(f"{c['name']:>28}: tuned {row['tuned']} {row['tuned_us']:9.1f}us"
              f"  default {row['default_us']:9.1f}us"
              f"  speedup {row['speedup_vs_default']:.2f}x"
              f"  ({row['n_candidates']} candidates)", flush=True)
    attn_sp = [r["speedup_vs_default"] for r in out["configs"].values()
               if r["kind"] == "attention"]
    sp = [r["speedup_vs_default"] for r in out["configs"].values()]
    out["summary"] = {
        "n_configs": len(sp),
        "min_speedup": round(min(sp), 3),
        "attention_geomean_speedup": round(
            math.exp(sum(math.log(s) for s in attn_sp) / len(attn_sp)), 3)
        if attn_sp else None,
        "timing_calls": tuner.timing_calls,
    }
    print(f"geomean attention speedup "
          f"{out['summary']['attention_geomean_speedup']}x, "
          f"min {out['summary']['min_speedup']}x "
          f"[{out['mode']} mode]", flush=True)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help=f"CI subset: {', '.join(SMOKE_NAMES)}")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()
    out = run(smoke=args.smoke, iters=args.iters, warmup=args.warmup)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
