"""Table 1: computational costs across pipeline configurations, plus the
paper's headline claims.

Reproduced quantities:
  * per-(asset, platform) duration / total / surcharge / storage rows,
    compared against Table 1's published values;
  * >= 40% cost reduction of the orchestrated policy vs all-premium (DBR);
  * the 12% EMR performance-improvement claim, reproduced in the
    platform-tuning reading (§6: node labels + maximizeResourceAllocation +
    doubled memory, Fig 4 cumulative effort): tuned-spot vs untuned-spot
    duration; the mix-vs-all-spot makespan delta is reported alongside.
"""
from __future__ import annotations

import statistics

from benchmarks.cc_pipeline import (PROFILES, SMALL, build_graph,  # noqa: F401
                                    run_policy)
from repro.core import CostModel, default_catalog
from repro.core.platforms import Platform

# Table 1 reference rows (run, step, platform, duration_h, total_usd)
TABLE1 = [
    ("nodes", "pod-spot", 0.39, 0.41),       # EMR avg of runs 1, 3
    ("edges", "pod-spot", 8.58, 405.8),      # EMR avg
    ("graph", "pod-spot", 0.94, 4.71),
    ("graph_aggr", "pod-spot", 0.25, 2.3),
    ("nodes", "pod-premium", 0.23, 0.50),    # DBR run 5
    ("edges", "pod-premium", 5.71, 766.2),
    ("graph", "pod-premium", 0.38, 17.7),
    ("graph_aggr", "pod-premium", 0.11, 0.93),
]



def per_cell_table() -> list[dict]:
    cm = CostModel()
    catalog = default_catalog()
    g = build_graph(partitions=SMALL)
    rows = []
    for name in ("nodes", "edges", "graph", "graph_aggr"):
        for plat in ("pod-spot", "pod-premium"):
            est = cm.estimate(g[name], catalog[plat])
            rows.append({
                "asset": name, "platform": plat,
                "duration_h": est.duration_s / 3600.0,
                "base_usd": est.base_usd,
                "surcharge_usd": est.surcharge_usd,
                "storage_usd": est.storage_usd,
                "total_usd": est.total_usd,
            })
    return rows


MIX = {"nodes": "pod-spot", "edges": "pod-spot", "graph": "pod-premium",
       "graph_aggr": "pod-spot"}  # Table 1 run 1


def headline_claims(n_seeds: int = 12) -> dict:
    cm = CostModel()
    catalog = default_catalog()
    g = build_graph(partitions=SMALL)

    # ---- Table-1 basis (steady-state cost model, the paper's own basis) ---
    mix_cost_t = sum(cm.estimate(g[a], catalog[MIX[a]]).total_usd
                     for a in PROFILES)
    prem_cost_t = sum(cm.estimate(g[a], catalog["pod-premium"]).total_usd
                      for a in PROFILES)
    cost_reduction_table = 1.0 - mix_cost_t / prem_cost_t

    # ---- simulated basis: failures + retries + duration jitter billed -----
    mix_cost, prem_cost, mix_span, spot_span = [], [], [], []
    for seed in range(n_seeds):
        r_mix, _ = run_policy("paper-mix", seed=seed, partitions=SMALL)
        r_prem, _ = run_policy("all-premium", seed=seed, partitions=SMALL)
        r_spot, _ = run_policy("all-spot", seed=seed, partitions=SMALL)
        mix_cost.append(r_mix.total_cost)
        prem_cost.append(r_prem.total_cost)
        mix_span.append(r_mix.makespan_s())
        spot_span.append(r_spot.makespan_s())
    cost_reduction_sim = 1.0 - statistics.mean(mix_cost) / statistics.mean(prem_cost)
    span_improvement = 1.0 - statistics.mean(mix_span) / statistics.mean(spot_span)

    # ---- 12% performance claim, platform-tuning reading (§6 / Fig 4):
    # the iterative EMR tuning (YARN node labels, maximizeResourceAllocation,
    # doubled memory) raised spot throughput; untuned = perf_factor 0.88.
    # Measured on the chip-capped production asset (edges dominates the
    # pipeline; right-sized small assets re-absorb perf into cluster size).
    spot = catalog["pod-spot"]
    untuned = Platform(**{**spot.__dict__, "name": "pod-spot-untuned",
                          "perf_factor_base": 0.88})
    tuned_s = cm.estimate(g["edges"], spot).compute_s
    untuned_s = cm.estimate(g["edges"], untuned).compute_s
    tuning_improvement = 1.0 - tuned_s / untuned_s

    return {
        "cost_reduction_vs_premium_table_basis": cost_reduction_table,
        "cost_reduction_vs_premium_simulated": cost_reduction_sim,
        "makespan_improvement_vs_spot_simulated": span_improvement,
        "tuning_improvement_vs_untuned_spot": tuning_improvement,
        "mix_cost_usd_table_basis": mix_cost_t,
        "premium_cost_usd_table_basis": prem_cost_t,
        "savings_usd_per_run": prem_cost_t - mix_cost_t,
    }


def run() -> dict:
    rows = per_cell_table()
    # compare against Table 1 reference (duration within 15%, cost within 25%
    # except the small graph/premium row — DESIGN.md §7 notes the deviation)
    err = []
    for asset_name, plat, ref_h, ref_usd in TABLE1:
        row = next(r for r in rows
                   if r["asset"] == asset_name and r["platform"] == plat)
        dur = row["duration_h"]
        err.append({
            "asset": asset_name, "platform": plat,
            "duration_model_h": round(dur, 3), "duration_table_h": ref_h,
            "duration_rel_err": round(abs(dur - ref_h) / ref_h, 3),
            "cost_model_usd": round(row["total_usd"], 2),
            "cost_table_usd": ref_usd,
            "cost_rel_err": round(abs(row["total_usd"] - ref_usd)
                                  / max(ref_usd, 0.01), 3),
        })
    claims = headline_claims()
    return {"cells": rows, "vs_table1": err, "claims": claims}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
