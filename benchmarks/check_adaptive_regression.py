"""CI gate: compare a fresh BENCH_adaptive(_smoke).json against the
committed baseline and fail on closed-loop-adaptation regressions.

Usage (what .github/workflows/ci.yml runs after ``adaptive_drift.py --smoke``):

    python benchmarks/check_adaptive_regression.py \
        --current BENCH_adaptive_smoke.json \
        --baseline benchmarks/baselines/adaptive_drift_baseline.json

Two kinds of check:

* **correctness booleans** — every entry in the current run's ``checks``
  must hold (zero-drift parity, no spurious replans, severe-drift wins,
  every run ok).  These are machine-independent semantics over *simulated*
  makespan/cost, so any failure is a regression outright.
* **severe-drift floors** — makespan and cost reduction at the severe
  level must stay above the baseline's floors.  The floors (15% / 5%) sit
  far below the observed values (~80% / ~70%), so only a genuine
  closed-loop regression — drift never detected, replan never adopted, the
  migration mispriced — can trip them; fault-injection noise cannot.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_adaptive_smoke.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/adaptive_drift_baseline.json")
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    failures: list[str] = []
    for name, ok in sorted(cur.get("checks", {}).items()):
        if not ok:
            failures.append(f"check failed: {name}")

    severe = cur.get("levels", {}).get("severe", {})
    mk_red = severe.get("makespan_reduction", 0.0)
    cost_red = severe.get("cost_reduction", 0.0)
    mk_floor = base.get("min_severe_makespan_reduction", 0.15)
    cost_floor = base.get("min_severe_cost_reduction", 0.05)
    if mk_red < mk_floor:
        failures.append(f"severe makespan reduction {mk_red:.1%} below the "
                        f"{mk_floor:.0%} floor")
    if cost_red < cost_floor:
        failures.append(f"severe cost reduction {cost_red:.1%} below the "
                        f"{cost_floor:.0%} floor")
    replans = severe.get("closed", {}).get("replans_adopted", 0)
    if replans < 1:
        failures.append("closed loop adopted no replan under severe drift")

    print(f"adaptive drift gate: severe makespan -{mk_red:.1%} "
          f"(floor {mk_floor:.0%}), cost -{cost_red:.1%} "
          f"(floor {cost_floor:.0%}), {len(cur.get('checks', {}))} checks")
    if failures:
        for fmsg in failures:
            print(f"REGRESSION: {fmsg}", file=sys.stderr)
        return 1
    print("OK: no closed-loop adaptation regression")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
