"""Fig 3: stacked run outcomes (success / failure / preemption / cancelled)
by platform under fault injection, and the ~2x trial-run gap between the
cheap and the managed platform before production stability.
"""
from __future__ import annotations

from benchmarks.cc_pipeline import SMALL, run_policy
from repro.core.telemetry import OUTCOME_KEYS


def run(n_seeds: int = 10) -> dict:
    counts = {"pod-spot": {k: 0 for k in OUTCOME_KEYS},
              "pod-premium": {k: 0 for k in OUTCOME_KEYS}}
    attempts = {"pod-spot": [], "pod-premium": []}
    for seed in range(n_seeds):
        for policy, plat in (("all-spot", "pod-spot"),
                             ("all-premium", "pod-premium")):
            report, reader = run_policy(policy, seed=100 + seed,
                                        partitions=SMALL)
            oc = reader.outcome_counts().get(
                plat, {k: 0 for k in OUTCOME_KEYS})
            for k in counts[plat]:
                counts[plat][k] += oc.get(k, 0)
            attempts[plat].append(
                sum(len(r.attempts) for r in report.records))
    spot_attempts = sum(attempts["pod-spot"]) / max(1, n_seeds)
    prem_attempts = sum(attempts["pod-premium"]) / max(1, n_seeds)
    spot_runs = counts["pod-spot"]
    prem_runs = counts["pod-premium"]
    spot_fail_rate = spot_runs["failure"] / max(
        1, sum(spot_runs.values()))
    prem_fail_rate = prem_runs["failure"] / max(
        1, sum(prem_runs.values()))
    return {
        "outcomes": counts,
        "mean_attempts_per_pipeline": {"pod-spot": spot_attempts,
                                       "pod-premium": prem_attempts},
        "trial_ratio_spot_over_premium": spot_attempts / max(prem_attempts, 1e-9),
        "failure_rate": {"pod-spot": spot_fail_rate,
                         "pod-premium": prem_fail_rate},
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
