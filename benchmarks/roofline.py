"""§Roofline: read the dry-run artifacts and render the 40-cell table
(three terms in seconds, dominant bottleneck, MODEL_FLOPS ratio, MFU)."""
from __future__ import annotations

import glob
import json
import os

ART_DIR = os.environ.get("REPRO_DRYRUN_DIR", "artifacts/dryrun")


def load_cells(mesh: str | None = "16x16") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        if mesh is None or c.get("mesh") == mesh:
            cells.append(c)
    return cells


def table(mesh: str = "16x16") -> list[dict]:
    rows = []
    for c in load_cells(mesh):
        base = {"arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"]}
        if c["status"] != "ok":
            rows.append({**base, "status": c["status"],
                         "note": c.get("reason", c.get("error", ""))[:80]})
            continue
        r = c["roofline"]
        rows.append({
            **base, "status": "ok",
            "t_compute_s": round(r["t_compute_s"], 5),
            "t_memory_s": round(r["t_memory_s"], 5),
            "t_collective_s": round(r["t_collective_s"], 5),
            "bottleneck": r["bottleneck"],
            "step_s": round(r["step_time_s"], 5),
            "mfu": round(r["model_flops_util"], 4),
            "useful_flops": round(r["useful_flops_ratio"], 3),
            "model_flops": f"{c['model_flops']:.3e}",
            "compile_s": c["compile_s"],
        })
    return rows


def run() -> dict:
    rows = table("16x16")
    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    errors = [r for r in rows if r["status"] == "error"]
    multi = [r for r in table("2x16x16") if r["status"] == "ok"]
    train = [r for r in ok if r["shape"] == "train_4k"]
    prefill = [r for r in ok if r["shape"] == "prefill_32k"]
    return {
        "rows": rows,
        "n_ok": len(ok), "n_skipped": len(skipped), "n_error": len(errors),
        "n_multipod_ok": len(multi),
        "bottleneck_histogram": {
            b: sum(1 for r in ok if r["bottleneck"] == b)
            for b in ("compute", "memory", "collective")},
        "mean_mfu": (sum(r["mfu"] for r in ok) / len(ok)) if ok else 0.0,
        # decode cells are intrinsically ~0.1% MFU (1 token vs all weights);
        # the train/prefill means are the meaningful utilisation numbers
        "mean_mfu_train": (sum(r["mfu"] for r in train) / len(train)
                           if train else 0.0),
        "mean_mfu_prefill": (sum(r["mfu"] for r in prefill) / len(prefill)
                             if prefill else 0.0),
        "best_mfu_train": max((r["mfu"] for r in train), default=0.0),
    }


def render(rows: list[dict]) -> str:
    hdr = (f"{'arch':<22} {'shape':<12} {'status':<8} {'compute':>9} "
           f"{'memory':>9} {'collect':>9} {'bottleneck':<11} {'MFU':>6}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"{r['arch']:<22} {r['shape']:<12} {r['status']:<8} "
                         f"{r.get('note', '')[:50]}")
            continue
        lines.append(
            f"{r['arch']:<22} {r['shape']:<12} {r['status']:<8} "
            f"{r['t_compute_s']:>9.4f} {r['t_memory_s']:>9.4f} "
            f"{r['t_collective_s']:>9.4f} {r['bottleneck']:<11} "
            f"{r['mfu']:>6.3f}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(render(table("16x16")))
    print()
    print(json.dumps({k: v for k, v in run().items() if k != "rows"},
                     indent=1))
