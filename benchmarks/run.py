"""Benchmark runner — one entry per paper table/figure plus the roofline and
substrate microbenchmarks.  Prints ``name,us_per_call,derived`` CSV.

``--smoke`` runs the fast orchestration-only subset (no jax compiles) and
writes ``BENCH_smoke.json`` for the CI artifact upload."""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# make `python benchmarks/run.py` equivalent to `python -m benchmarks.run`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return (time.perf_counter() - t0) * 1e6, out


def _planner_row(rows, smoke: bool):
    from benchmarks import planner_vs_greedy
    us, pv = _timed(lambda: planner_vs_greedy.run(smoke=smoke))
    s = pv["summary"]
    rows.append(("planner_vs_greedy", us,
                 f"dominates={s['n_dominates']}/{s['n_configs']};"
                 f"max_saving_pct={s['max_cost_saving_pct']:.1f}"))
    return pv


def _print_rows(rows) -> None:
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def smoke() -> None:
    """Fast subset for CI: Table-1 economics + planner sweep (~seconds)."""
    rows: list[tuple[str, float, str]] = []

    from benchmarks import table1_cost
    us, t1 = _timed(table1_cost.run)
    claims = t1["claims"]
    rows.append(("table1_cost", us,
                 f"cost_reduction_vs_premium="
                 f"{claims['cost_reduction_vs_premium_table_basis']:.3f}"))
    pv = _planner_row(rows, smoke=True)
    _print_rows(rows)
    with open("BENCH_smoke.json", "w") as f:
        json.dump({"table1": t1, "planner_vs_greedy": pv}, f, indent=1,
                  default=float)


def main() -> None:
    rows: list[tuple[str, float, str]] = []

    from benchmarks import table1_cost
    us, t1 = _timed(table1_cost.run)
    claims = t1["claims"]
    rows.append(("table1_cost", us,
                 f"cost_reduction_vs_premium={claims['cost_reduction_vs_premium_table_basis']:.3f};"
                 f"cost_reduction_simulated={claims['cost_reduction_vs_premium_simulated']:.3f};"
                 f"tuning_improvement={claims['tuning_improvement_vs_untuned_spot']:.3f};"
                 f"savings_usd={claims['savings_usd_per_run']:.0f}"))

    from benchmarks import fig3_reliability
    us, f3 = _timed(lambda: fig3_reliability.run(n_seeds=6))
    rows.append(("fig3_reliability", us,
                 f"trial_ratio={f3['trial_ratio_spot_over_premium']:.2f};"
                 f"spot_fail={f3['failure_rate']['pod-spot']:.2f};"
                 f"premium_fail={f3['failure_rate']['pod-premium']:.2f}"))

    from benchmarks import fig4_effort
    us, f4 = _timed(fig4_effort.run)
    rows.append(("fig4_effort", us,
                 f"trial_ratio={f4['trial_ratio_spot_over_premium']:.2f};"
                 f"spot_changes={f4['pod-spot']['mean_changes']:.1f};"
                 f"premium_changes={f4['pod-premium']['mean_changes']:.1f}"))

    from benchmarks import fig5_cost_by_asset
    us, f5 = _timed(fig5_cost_by_asset.run)
    rows.append(("fig5_cost_by_asset", us,
                 f"orchestrated_total={f5['orchestrated']['total_cost']:.0f};"
                 f"premium_total={f5['all-premium']['total_cost']:.0f}"))

    from benchmarks import fig6_durations
    us, f6 = _timed(lambda: fig6_durations.run(n_seeds=6))
    edges_ratio = (f6["edges@pod-spot"]["median_h"]
                   / f6["edges@pod-premium"]["median_h"])
    rows.append(("fig6_durations", us,
                 f"edges_spot_over_premium={edges_ratio:.2f}"))

    from benchmarks import roofline
    us, rf = _timed(roofline.run)
    rows.append(("roofline", us,
                 f"ok={rf['n_ok']};skipped={rf['n_skipped']};"
                 f"errors={rf['n_error']};multipod_ok={rf['n_multipod_ok']};"
                 f"mean_mfu_train={rf['mean_mfu_train']:.3f};"
                 f"best_mfu_train={rf['best_mfu_train']:.3f};"
                 f"mean_mfu_prefill={rf['mean_mfu_prefill']:.3f}"))

    from benchmarks import lm_platform_choice
    us, lm = _timed(lm_platform_choice.run)
    train_cells = {k: v for k, v in lm.items() if "train" in k}
    prem = sum(1 for v in train_cells.values()
               if v["platform"] == "pod-premium")
    rows.append(("lm_platform_choice", us,
                 f"cells={len(lm)};train_on_premium={prem}/"
                 f"{len(train_cells)}"))

    pv = _planner_row(rows, smoke=False)

    from benchmarks import microbench
    for name, val in microbench.run().items():
        rows.append((f"micro_{name}", val["us"],
                     f"std_us={val['std_us']:.1f};iters={val['iters']}"))

    _print_rows(rows)

    with open("artifacts/bench_results.json", "w") as f:
        json.dump({"table1": t1, "fig3": f3, "fig4": f4, "fig5": f5,
                   "fig6": f6, "lm_platform_choice": lm,
                   "planner_vs_greedy": pv,
                   "roofline": {k: v for k, v in rf.items() if k != "rows"}},
                  f, indent=1, default=float)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast orchestration-only subset; writes "
                         "BENCH_smoke.json")
    if ap.parse_args().smoke:
        smoke()
    else:
        main()
