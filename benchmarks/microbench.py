"""Substrate microbenchmarks (wall-clock on this host's CPU device; the
numbers feed the us_per_call CSV column and regression-track the XLA paths)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _bench(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def attention_core_us() -> float:
    from repro.models.attention import attention_core
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, D = 1, 2048, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    fn = jax.jit(lambda q, k, v: attention_core(q, k, v, pos, pos,
                                                causal=True))
    return _bench(fn, q, k, v)


def wkv_chunked_us() -> float:
    from repro.models.recurrent import wkv_chunked
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    B, S, H, N = 1, 1024, 4, 64
    r, k, v = (jax.random.normal(ks[i], (B, S, H, N), jnp.float32)
               for i in range(3))
    lw = -jnp.exp(jax.random.uniform(ks[3], (B, S, H, N), jnp.float32,
                                     -6.0, 0.0))
    u = jax.random.normal(ks[4], (H, N), jnp.float32) * 0.1
    s0 = jnp.zeros((B, H, N, N), jnp.float32)
    fn = jax.jit(lambda *a: wkv_chunked(*a)[0])
    return _bench(fn, r, k, v, lw, u, s0)


def moe_dense_us() -> float:
    from repro.configs import get_config
    from repro.models import moe as moe_mod
    cfg = get_config("granite-moe-1b-a400m").scaled(
        d_model=256, n_experts=8, top_k=2, d_ff_expert=128)
    p, _ = _split(moe_mod.init_moe(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128, 256), jnp.bfloat16)
    fn = jax.jit(lambda p, x: moe_mod.apply_moe(p, cfg, x)[0])
    return _bench(fn, p, x)


def _split(tree):
    from repro.models.layers import split
    return split(tree)


def train_step_us() -> float:
    from repro.launch.train import make_train_step, smoke_config
    from repro.models import LanguageModel
    from repro.optim import AdamW, OptConfig
    cfg = smoke_config("deepseek-7b")
    model = LanguageModel(cfg)
    opt = AdamW(OptConfig())
    params = model.init(jax.random.PRNGKey(0))
    state = opt.init(params)
    rng = np.random.RandomState(0)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 64)), jnp.int32),
        "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 64)), jnp.int32),
        "weights": jnp.ones((4, 64), jnp.float32),
    }
    step = make_train_step(model, opt)
    params, state, _ = step(params, state, batch)  # compile + donate warmup

    def run_once():
        nonlocal params, state
        params, state, m = step(params, state, batch)
        return m["loss"]

    t0 = time.perf_counter()
    iters = 5
    for _ in range(iters):
        out = run_once()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> dict:
    return {
        "attention_core_2k": attention_core_us(),
        "wkv_chunked_1k": wkv_chunked_us(),
        "moe_dense_small": moe_dense_us(),
        "train_step_smoke_7b_cfg": train_step_us(),
    }


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v:.1f} us")
