"""Substrate microbenchmarks (wall-clock on this host's CPU device; the
numbers feed the us_per_call CSV column and regression-track the XLA paths).

Every timed iteration is individually bracketed by ``block_until_ready`` so
async dispatch can neither pipeline across iterations nor hide a slow final
call, and each benchmark reports the per-iteration standard deviation next
to the mean — a high ``std_us`` flags a noisy cell before anyone chases a
phantom regression.  The two Pallas kernels run here in interpret mode, so
CPU-only CI exercises the real kernel bodies (not just the XLA reference
paths) on every push.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _stats(samples: list[float]) -> dict:
    mean = sum(samples) / len(samples)
    var = sum((s - mean) ** 2 for s in samples) / len(samples)
    return {"us": mean, "std_us": var ** 0.5, "iters": len(samples)}


def _bench(fn, *args, iters: int = 5, warmup: int = 2) -> dict:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return _stats(samples)


def attention_core_us() -> dict:
    from repro.models.attention import attention_core
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, D = 1, 2048, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    fn = jax.jit(lambda q, k, v: attention_core(q, k, v, pos, pos,
                                                causal=True))
    return _bench(fn, q, k, v)


def flash_attention_pallas_us() -> dict:
    """The Pallas flash kernel itself, interpret mode (CPU CI)."""
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, H, D = 1, 512, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.bfloat16)
    return _bench(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, tuned=True, interpret=True), q, k, v, iters=3)


def wkv_chunked_us() -> dict:
    from repro.models.recurrent import wkv_chunked
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    B, S, H, N = 1, 1024, 4, 64
    r, k, v = (jax.random.normal(ks[i], (B, S, H, N), jnp.float32)
               for i in range(3))
    lw = -jnp.exp(jax.random.uniform(ks[3], (B, S, H, N), jnp.float32,
                                     -6.0, 0.0))
    u = jax.random.normal(ks[4], (H, N), jnp.float32) * 0.1
    s0 = jnp.zeros((B, H, N, N), jnp.float32)
    fn = jax.jit(lambda *a: wkv_chunked(*a)[0])
    return _bench(fn, r, k, v, lw, u, s0)


def wkv_scan_pallas_us() -> dict:
    """The Pallas WKV linear-scan kernel itself, interpret mode (CPU CI)."""
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, H, N = 1, 512, 2, 64
    r, k, v = (jax.random.normal(ks[i], (B, S, H, N), jnp.float32)
               for i in range(3))
    lw = -jnp.exp(jax.random.uniform(ks[3], (B, S, H, N), jnp.float32,
                                     -6.0, 0.0))
    u = jax.random.normal(ks[4], (H, N), jnp.float32) * 0.1
    s0 = jnp.zeros((B, H, N, N), jnp.float32)
    return _bench(lambda *a: ops.linear_scan(*a, tuned=True,
                                             interpret=True)[0],
                  r, k, v, lw, u, s0, iters=3)


def moe_dense_us() -> dict:
    from repro.configs import get_config
    from repro.models import moe as moe_mod
    cfg = get_config("granite-moe-1b-a400m").scaled(
        d_model=256, n_experts=8, top_k=2, d_ff_expert=128)
    p, _ = _split(moe_mod.init_moe(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128, 256), jnp.bfloat16)
    fn = jax.jit(lambda p, x: moe_mod.apply_moe(p, cfg, x)[0])
    return _bench(fn, p, x)


def _split(tree):
    from repro.models.layers import split
    return split(tree)


def train_step_us() -> dict:
    from repro.launch.train import make_train_step, smoke_config
    from repro.models import LanguageModel
    from repro.optim import AdamW, OptConfig
    cfg = smoke_config("deepseek-7b")
    model = LanguageModel(cfg)
    opt = AdamW(OptConfig())
    params = model.init(jax.random.PRNGKey(0))
    state = opt.init(params)
    rng = np.random.RandomState(0)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 64)), jnp.int32),
        "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 64)), jnp.int32),
        "weights": jnp.ones((4, 64), jnp.float32),
    }
    step = make_train_step(model, opt)
    params, state, _ = step(params, state, batch)  # compile + donate warmup

    samples = []
    for _ in range(5):
        t0 = time.perf_counter()
        params, state, m = step(params, state, batch)
        jax.block_until_ready(m["loss"])
        samples.append((time.perf_counter() - t0) * 1e6)
    return _stats(samples)


def run() -> dict:
    return {
        "attention_core_2k": attention_core_us(),
        "flash_attention_pallas_512": flash_attention_pallas_us(),
        "wkv_chunked_1k": wkv_chunked_us(),
        "wkv_scan_pallas_512": wkv_scan_pallas_us(),
        "moe_dense_small": moe_dense_us(),
        "train_step_smoke_7b_cfg": train_step_us(),
    }


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v['us']:.1f} us  (+/- {v['std_us']:.1f} us, "
              f"n={v['iters']})")
