"""Planner scale sweep: the incremental, slot-aware scheduling engine vs the
legacy full-recompute planner on 100 -> 10,000-task DAGs.

``_LegacyPlanner`` below is a faithful port of the PR-2 ``RunPlanner``: an
infinite-width critical-path schedule re-run over all *n* tasks for every
upgrade/downgrade candidate trial.  The current ``RunPlanner`` replaces that
with ``core.schedule.ScheduleEngine`` — O(cone) incremental retiming, lazy
slack, vectorized pricing and a finite-capacity list schedule.

For every (shape x size) cell we time both planners and evaluate both plans
under the *same* slot-aware evaluator (``SlotConfig()`` — the coordinator's
execution limits), so the quality comparison reflects realized makespans,
not the legacy planner's infinite-width beliefs:

* ``makespan_ok`` — the new plan's realized (slot-aware) makespan is never
  worse than legacy's;
* ``cost_ok`` — the new plan costs no more than legacy (0.5% tolerance for
  upgrade-ordering noise: batched rounds occasionally buy a different but
  equally-critical sibling than legacy's one-at-a-time loop), *or* legacy's
  plan broke the planner contract — realized makespan slower than greedy as
  executed — in which case its lower sticker price bought a plan the
  planner is not allowed to return.

On fan-out shapes the legacy planner looks fast: its infinite-width model
sees no contention, so it skips nearly all optimization work — and ships a
plan whose realized makespan exceeds the greedy envelope.  The speedup
headline therefore reports the geometric mean across shapes alongside the
per-shape numbers.

Writes ``BENCH_planner_scale.json``; CI's bench-smoke job re-runs the
100/1,000 sizes (``--smoke``) and ``check_planner_regression.py`` fails on a
>1.5x plan-time regression at 1,000 tasks vs the committed baseline.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# make `python benchmarks/planner_scale.py` == `python -m benchmarks.planner_scale`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from repro.core import (AssetGraph, ComputeProfile, CostModel,  # noqa: E402
                        DynamicClientFactory, Objective, RunPlanner,
                        ScheduleEngine, SlotConfig, asset, default_catalog,
                        task_dag)
from repro.core.partitions import StaticPartitions  # noqa: E402

SIZES = (100, 1000, 10000)
SMOKE_SIZES = (100, 1000)
TIME_VALUE = 600.0


# --------------------------------------------------------------- DAG shapes
def _work(i: int) -> float:
    """Deterministic per-task work variation so upgrade/downgrade moves
    exist at every scale."""
    return 20.0 + (i % 7) * 33.0


def _cls(i: int) -> str:
    return ("scan", "shuffle", "light")[i % 3]


def _leaf(name: str, work: float, cls: str = "scan", deps=(), parts=None):
    return asset(name=name, deps=deps, partitions=parts,
                 compute=ComputeProfile(work_chip_hours=work,
                                        speedup_class=cls, min_chips=8))(
        lambda ctx, **kw: name)


def chain_graph(n: int):
    """Pure chain: every task is critical."""
    specs = [_leaf("s0", _work(0))]
    for i in range(1, n):
        specs.append(_leaf(f"s{i:05d}", _work(i), _cls(i),
                           deps=(specs[-1].name,)))
    return AssetGraph(specs), [specs[-1].name]


def fanout_graph(n: int):
    """One source, n-2 parallel branches, one sink — maximal slot pressure."""
    specs = [_leaf("src", 5.0)]
    for i in range(n - 2):
        specs.append(_leaf(f"b{i:05d}", _work(i), _cls(i), deps=("src",)))
    specs.append(_leaf("sink", 5.0, "light",
                       deps=tuple(s.name for s in specs[1:])))
    return AssetGraph(specs), ["sink"]


def diamond_graph(n: int):
    """Back-to-back unbalanced diamonds (width 4)."""
    specs = [_leaf("d00000", _work(0))]
    i = 1
    while len(specs) < n - 4:
        top = specs[-1].name
        mids = []
        for w in range(4):
            s = _leaf(f"d{i:05d}", _work(i + w) * (3.0 if w == 0 else 1.0),
                      _cls(i + w), deps=(top,))
            specs.append(s)
            mids.append(s.name)
            i += 1
        specs.append(_leaf(f"d{i:05d}", 10.0, "light", deps=tuple(mids)))
        i += 1
    return AssetGraph(specs), [specs[-1].name]


def partitioned_graph(n: int):
    """Partitioned fan-in: the Common-Crawl shape at scale."""
    parts = StaticPartitions(tuple(f"p{i:05d}" for i in range(max(2, n - 1))))
    shards = _leaf("shards", 120.0, parts=parts)
    merged = _leaf("merged", 40.0, "shuffle", deps=("shards",))
    return AssetGraph([shards, merged]), ["merged"]


SHAPES = {
    "chain": chain_graph,
    "fanout": fanout_graph,
    "diamond": diamond_graph,
    "partitioned_fanin": partitioned_graph,
}


# ------------------------------------------------------ legacy (PR-2) port
class _LegacyPlanner:
    """The pre-engine planner: full critical-path reschedule per candidate
    trial, infinite platform width, per-task Python pricing loops.  Kept
    here (not in src/) purely as the benchmark baseline."""

    def __init__(self, graph, factory, max_iterations: int = 1000):
        self.graph = graph
        self.factory = factory
        self.max_iterations = max_iterations

    def _tasks(self, targets):
        from repro.core.partitions import dep_partition_keys, partition_keys
        order = self.graph.topo_order(targets)
        keys, preds = [], {}
        for name in order:
            spec = self.graph[name]
            for key in partition_keys(spec.partitions):
                tk = (name, key)
                keys.append(tk)
                preds[tk] = [
                    (d, dk) for d in spec.deps
                    for dk in dep_partition_keys(
                        self.graph[d].partitions, key)]
        return keys, preds

    def _candidates(self, keys):
        cm = self.factory.cost_model
        by_asset, out = {}, {}
        for name, _part in keys:
            if name not in by_asset:
                spec = self.graph[name]
                cands = []
                for pname, platform in self.factory.catalog.items():
                    if spec.platform_hint and pname != spec.platform_hint:
                        continue
                    est = cm.estimate(spec, platform)
                    if not est.feasible:
                        continue
                    cands.append((pname,
                                  cm.expected_cost_with_retries(est, platform),
                                  est.duration_s))
                by_asset[name] = cands
            out[(name, _part)] = by_asset[name]
        return out

    @staticmethod
    def _schedule(keys, preds, durations):
        finish = {}
        for tk in keys:
            start = max((finish[p] for p in preds[tk]), default=0.0)
            finish[tk] = start + durations[tk]
        makespan = max(finish.values(), default=0.0)
        succs = {tk: [] for tk in keys}
        for tk in keys:
            for p in preds[tk]:
                succs[p].append(tk)
        latest = {}
        for tk in reversed(keys):
            latest[tk] = min(
                (latest[s] - durations[s] for s in succs[tk]),
                default=makespan)
        slack = {tk: latest[tk] - finish[tk] for tk in keys}
        return makespan, slack

    def plan(self, targets, objective):
        obj = objective
        keys, preds = self._tasks(targets)
        cands = self._candidates(keys)
        durations = lambda assign: {tk: c[2] for tk, c in assign.items()}
        tv = obj.time_value_usd_per_hour
        greedy = {tk: min(cs, key=lambda c: c[1] + tv * c[2] / 3600.0)
                  for tk, cs in cands.items()}
        greedy_ms, _ = self._schedule(keys, preds, durations(greedy))
        target_ms = greedy_ms
        assign = {tk: min(cs, key=lambda c: (c[1], c[2]))
                  for tk, cs in cands.items()}
        iters = 0
        ms, slack = self._schedule(keys, preds, durations(assign))
        eps = 1e-9
        while ms > target_ms and iters < self.max_iterations:
            iters += 1
            best = None
            for tk in keys:
                if slack[tk] > eps * max(ms, 1.0):
                    continue
                cur = assign[tk]
                for c in cands[tk]:
                    saved = cur[2] - c[2]
                    if saved <= 0:
                        continue
                    rate = saved / max(c[1] - cur[1], 1e-9)
                    if best is None or rate > best[0]:
                        best = (rate, tk, c)
            if best is None:
                break
            assign[best[1]] = best[2]
            ms, slack = self._schedule(keys, preds, durations(assign))
        if ms > greedy_ms * (1 + 1e-9):
            assign = dict(greedy)
            ms, slack = self._schedule(keys, preds, durations(assign))
        improved = True
        while improved and iters < self.max_iterations:
            improved = False
            for tk in sorted(keys, key=lambda k: -slack[k]):
                cur = assign[tk]
                for c in sorted(cands[tk], key=lambda c: c[1]):
                    if c[1] >= cur[1]:
                        break
                    if c[2] > cur[2] + slack[tk]:
                        continue
                    trial = dict(assign)
                    trial[tk] = c
                    tms, tslack = self._schedule(keys, preds,
                                                 durations(trial))
                    if tms <= max(ms, target_ms) * (1 + 1e-12):
                        assign, ms, slack = trial, tms, tslack
                        improved = True
                        iters += 1
                        break
        return {tk: {"platform": c[0], "cost": c[1], "dur": c[2]}
                for tk, c in assign.items()}, iters


# ------------------------------------------------------------- evaluation
def _evaluate(graph, targets, assignment: dict, slots: SlotConfig):
    """Slot-aware realized cost/makespan of any (task -> platform/cost/dur)
    assignment — the common yardstick for both planners."""
    keys, preds = task_dag(graph, targets)
    engine = ScheduleEngine(keys, preds, slots)
    engine.load([assignment[k]["dur"] for k in keys],
                [assignment[k]["platform"] for k in keys])
    sched = engine.slot_schedule()
    return (sum(a["cost"] for a in assignment.values()), sched.makespan_s)


def _factory():
    return DynamicClientFactory(default_catalog(), CostModel(),
                                Objective.balanced(TIME_VALUE))


def run_cell(shape: str, size: int, repeats: int = 3,
             with_legacy: bool = True) -> dict:
    graph, targets = SHAPES[shape](size)
    slots = SlotConfig()
    factory = _factory()

    best_new = float("inf")
    plan = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        plan = RunPlanner(graph, factory, slots=slots).plan(targets)
        best_new = min(best_new, time.perf_counter() - t0)
    new_assign = {tk: {"platform": c.platform, "cost": c.expected_cost_usd,
                       "dur": c.estimate.duration_s}
                  for tk, c in plan.choices.items()}
    new_cost, new_ms = _evaluate(graph, targets, new_assign, slots)
    greedy_env_ms = plan.greedy_makespan_s  # greedy as executed under slots

    out = {
        "n_tasks": len(plan.choices),
        "new": {"plan_time_s": round(best_new, 4),
                "cost_usd": round(new_cost, 2),
                "slot_makespan_h": round(new_ms / 3600.0, 3),
                "predicted_makespan_h": round(
                    plan.predicted_makespan_s / 3600.0, 3),
                "iterations": plan.iterations},
        "greedy_envelope_h": round(greedy_env_ms / 3600.0, 3),
    }
    if with_legacy:
        # best-of-2 at CI sizes so the normalized regression gate isn't at
        # the mercy of one noisy sub-100ms sample; single run at 10k where
        # legacy takes minutes
        legacy_t = float("inf")
        for _ in range(2 if size <= 1000 else 1):
            t0 = time.perf_counter()
            legacy_assign, legacy_iters = _LegacyPlanner(graph, factory).plan(
                targets, factory.objective)
            legacy_t = min(legacy_t, time.perf_counter() - t0)
        legacy_cost, legacy_ms = _evaluate(graph, targets, legacy_assign,
                                           slots)
        legacy_breaks_envelope = legacy_ms > greedy_env_ms * (1 + 1e-6)
        out["legacy"] = {"plan_time_s": round(legacy_t, 4),
                         "cost_usd": round(legacy_cost, 2),
                         "slot_makespan_h": round(legacy_ms / 3600.0, 3),
                         "iterations": legacy_iters,
                         "breaks_greedy_envelope": bool(
                             legacy_breaks_envelope)}
        out["speedup"] = round(legacy_t / max(best_new, 1e-9), 2)
        out["makespan_ok"] = bool(new_ms <= legacy_ms * (1 + 1e-6))
        out["cost_ok"] = bool(
            new_cost <= legacy_cost * 1.005 or legacy_breaks_envelope)
    return out


def run(sizes=SIZES, with_legacy: bool = True) -> dict:
    out: dict = {"time_value_usd_per_hour": TIME_VALUE,
                 "slots": dataclass_dict(SlotConfig()), "shapes": {}}
    worst = None
    for shape in SHAPES:
        out["shapes"][shape] = {}
        for size in sizes:
            cell = run_cell(shape, size, with_legacy=with_legacy)
            out["shapes"][shape][str(size)] = cell
            print(f"{shape:>18} n={size:>6}: new {cell['new']['plan_time_s']:.3f}s"
                  + (f"  legacy {cell['legacy']['plan_time_s']:.3f}s"
                     f"  speedup {cell['speedup']:.1f}x"
                     f"  cost_ok={cell['cost_ok']}"
                     f"  makespan_ok={cell['makespan_ok']}"
                     if with_legacy else ""),
                  flush=True)
            if with_legacy:
                if worst is None or cell["speedup"] < worst:
                    worst = cell["speedup"]
    if with_legacy:
        largest = str(max(sizes))
        at_largest = {s: out["shapes"][s][largest]["speedup"]
                      for s in SHAPES}
        geo = 1.0
        for v in at_largest.values():
            geo *= max(v, 1e-9)
        geo **= 1.0 / len(at_largest)
        out["summary"] = {
            "largest_size": int(largest),
            "min_speedup": worst,
            "speedup_at_largest": at_largest,
            "geomean_speedup_at_largest": round(geo, 2),
            "all_cost_ok": all(
                c["cost_ok"] for s in out["shapes"].values()
                for c in s.values()),
            "all_makespan_ok": all(
                c["makespan_ok"] for s in out["shapes"].values()
                for c in s.values()),
        }
    return out


def dataclass_dict(s: SlotConfig) -> dict:
    return {"max_concurrent": s.max_concurrent,
            "platform_slots": s.platform_slots,
            "elastic_max_slots": s.elastic_max_slots}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: sizes 100/1000 only")
    ap.add_argument("--out", default=None,
                    help="output path (default: BENCH_planner_scale.json, "
                         "or BENCH_planner_scale_smoke.json with --smoke so "
                         "a local smoke run never clobbers the committed "
                         "full artifact)")
    args = ap.parse_args()
    out = args.out or ("BENCH_planner_scale_smoke.json" if args.smoke
                       else "BENCH_planner_scale.json")
    sizes = SMOKE_SIZES if args.smoke else SIZES
    res = run(sizes=sizes)
    res["smoke"] = args.smoke
    with open(out, "w") as f:
        json.dump(res, f, indent=1, default=float)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
