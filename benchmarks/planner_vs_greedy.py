"""Planner vs greedy: sweep DAG shapes, compare predicted AND simulated
cost/makespan of the global ``RunPlanner`` against the per-task greedy
``DynamicClientFactory.choose``.

Each sweep configuration builds a graph, plans it, then *executes* both
policies through the ``RunCoordinator`` with deterministic simulated clients
(fault injection off, fixed run_ids) so the deltas are reproducible.  The
planner's contract — cost <= greedy at equal-or-better makespan — is checked
per configuration and summarized as ``n_dominates``.
"""
from __future__ import annotations

from repro.core import (AssetGraph, ComputeProfile, CostModel,
                        DynamicClientFactory, Objective, RunCoordinator,
                        RunPlanner, SimulatedClusterClient, StaticPartitions,
                        asset, default_catalog)

SCAN = "scan"


def _leaf(name: str, work: float, cls: str = SCAN, deps=(), parts=None):
    return asset(name=name, deps=deps, partitions=parts,
                 compute=ComputeProfile(work_chip_hours=work,
                                        speedup_class=cls, min_chips=8))(
        lambda ctx, **kw: name)


def chain_graph(n: int = 5) -> tuple[AssetGraph, list[str]]:
    """Pure chain: every task is critical — planner == greedy makespan."""
    specs = [_leaf("s0", 60.0)]
    for i in range(1, n):
        specs.append(_leaf(f"s{i}", 60.0, deps=(f"s{i-1}",)))
    return AssetGraph(specs), [f"s{n-1}"]


def fanout_graph(width: int = 6) -> tuple[AssetGraph, list[str]]:
    """One heavy critical branch, many light ones with slack."""
    specs = [_leaf("src", 10.0)]
    for i in range(width):
        work = 500.0 if i == 0 else 50.0
        specs.append(_leaf(f"b{i}", work, deps=("src",)))
    specs.append(_leaf("sink", 10.0, cls="light",
                       deps=tuple(f"b{i}" for i in range(width))))
    return AssetGraph(specs), ["sink"]


def diamond_graph() -> tuple[AssetGraph, list[str]]:
    """Two unbalanced diamonds back to back."""
    specs = [
        _leaf("a", 20.0),
        _leaf("b1", 300.0, deps=("a",)),
        _leaf("b2", 30.0, cls="shuffle", deps=("a",)),
        _leaf("c", 20.0, cls="light", deps=("b1", "b2")),
        _leaf("d1", 200.0, deps=("c",)),
        _leaf("d2", 25.0, cls="shuffle", deps=("c",)),
        _leaf("e", 10.0, cls="light", deps=("d1", "d2")),
    ]
    return AssetGraph(specs), ["e"]


def partitioned_graph() -> tuple[AssetGraph, list[str]]:
    """Partitioned fan-in, the Common-Crawl shape at benchmark scale."""
    parts = StaticPartitions(("p0", "p1", "p2"))
    shards = asset(name="shards", partitions=parts,
                   compute=ComputeProfile(work_chip_hours=120.0,
                                          speedup_class=SCAN, min_chips=8))(
        lambda ctx, **kw: 0)
    merged = _leaf("merged", 40.0, cls="shuffle", deps=("shards",))
    return AssetGraph([shards, merged]), ["merged"]


SWEEP = {
    "chain": chain_graph,
    "fanout": fanout_graph,
    "diamond": diamond_graph,
    "partitioned_fanin": partitioned_graph,
}


def _nofail_factory(objective: Objective) -> DynamicClientFactory:
    return DynamicClientFactory(
        default_catalog(), CostModel(), objective,
        client_builder=lambda p: SimulatedClusterClient(
            p, seed=0, failure_rate=0.0, preemption_rate=0.0))


def run_config(name: str, objective: Objective) -> dict:
    graph, targets = SWEEP[name]()
    factory = _nofail_factory(objective)
    plan = RunPlanner(graph, factory).plan(targets)

    # both policies share one run_id: the clients' jitter RNG is keyed on
    # (run_id, asset, partition, attempt, platform), so a task that lands on
    # the same platform draws the same duration under either policy — the
    # comparison is paired, not noisy
    greedy_rep = RunCoordinator(
        graph, _nofail_factory(objective), use_cache=False).materialize(
        targets, run_id=f"pvg-{name}")
    planned_rep = RunCoordinator(
        graph, _nofail_factory(objective), use_cache=False).materialize(
        targets, run_id=f"pvg-{name}", plan=plan)

    out = {
        "n_tasks": len(plan.choices),
        "predicted": {
            "greedy_cost": round(plan.greedy_cost_usd, 2),
            "planned_cost": round(plan.predicted_cost_usd, 2),
            "greedy_makespan_h": round(plan.greedy_makespan_s / 3600.0, 3),
            "planned_makespan_h": round(
                plan.predicted_makespan_s / 3600.0, 3),
        },
        "simulated": {
            "greedy_cost": round(greedy_rep.total_cost, 2),
            "planned_cost": round(planned_rep.total_cost, 2),
            "greedy_makespan_h": round(greedy_rep.makespan_s() / 3600.0, 3),
            "planned_makespan_h": round(
                planned_rep.makespan_s() / 3600.0, 3),
        },
        "iterations": plan.iterations,
    }
    out["dominates_predicted"] = (
        plan.predicted_cost_usd <= plan.greedy_cost_usd + 1e-9
        and plan.predicted_makespan_s <= plan.greedy_makespan_s + 1e-9)
    out["cost_saving_pct"] = round(
        100.0 * (1.0 - plan.predicted_cost_usd
                 / max(plan.greedy_cost_usd, 1e-9)), 2)
    return out


def run(smoke: bool = False,
        time_value: float = 600.0) -> dict:
    """Sweep all shapes.  ``smoke`` restricts to the two fastest graphs."""
    objective = Objective.balanced(time_value)
    names = ["chain", "fanout"] if smoke else list(SWEEP)
    out: dict = {n: run_config(n, objective) for n in names}
    out["summary"] = {
        "n_configs": len(names),
        "n_dominates": sum(1 for n in names
                           if out[n]["dominates_predicted"]),
        "max_cost_saving_pct": max(out[n]["cost_saving_pct"]
                                   for n in names),
    }
    assert out["summary"]["n_dominates"] == len(names), \
        "planner failed to match greedy on every sweep configuration"
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
