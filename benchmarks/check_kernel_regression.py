"""CI gate: compare a fresh BENCH_kernels.json against the committed
baseline and fail on kernel tile-tuning regression.

Usage (what .github/workflows/ci.yml runs after ``kernel_bench.py --smoke``):

    python benchmarks/check_kernel_regression.py \
        --current BENCH_kernels.json \
        --baseline benchmarks/baselines/kernel_bench_baseline.json \
        --max-ratio 1.5

Every kernel config present in both files is checked.  Raw microseconds are
machine-dependent (CI runners differ from the machine that recorded the
baseline), so the gate compares the *normalized* per-config metric —
``tuned_us / default_us`` — against the baseline's value: the fixed-default
tile runs in the same sweep on the same hardware, so machine speed cancels
and only a genuine tile-selection or kernel regression moves the ratio.
Sub-millisecond cells still jitter, so a regression additionally requires
the raw tuned time to exceed the baseline's by ``--min-delta-us``.

Two unconditional invariants are also enforced on the current run:

* ``speedup_vs_default >= 1.0`` for every config — the tuner must never
  ship a tile slower than the fixed default it replaced;
* the two runs were produced in the same mode (interpret vs tpu) — ratios
  across modes compare different machines and are meaningless.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_kernels.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/kernel_bench_baseline.json")
    ap.add_argument("--max-ratio", type=float, default=1.5)
    ap.add_argument("--min-delta-us", type=float, default=500.0,
                    help="absolute raw tuned-time excess a regression must "
                         "also show (noise floor for sub-ms cells)")
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    failures: list[str] = []
    if cur.get("mode") != base.get("mode"):
        failures.append(
            f"mode mismatch: current ran {cur.get('mode')!r} but baseline "
            f"is {base.get('mode')!r} — normalized ratios do not compare")

    checked = 0
    for name, b_row in sorted(base.get("configs", {}).items()):
        c_row = cur.get("configs", {}).get(name)
        if c_row is None:
            continue
        b = b_row["tuned_us"] / max(b_row["default_us"], 1e-9)
        c = c_row["tuned_us"] / max(c_row["default_us"], 1e-9)
        ratio = c / max(b, 1e-9)
        raw_delta = c_row["tuned_us"] - b_row["tuned_us"]
        regressed = ratio > args.max_ratio and raw_delta > args.min_delta_us
        status = "REGRESSION" if regressed else "OK"
        print(f"{name:>28}: tuned/default baseline {b:.3f} -> "
              f"current {c:.3f} ({ratio:.2f}x) {status} "
              f"[raw {c_row['tuned_us']:.0f}us, delta {raw_delta:+.0f}us]")
        checked += 1
        if regressed:
            failures.append(
                f"{name}: normalized tuned/default {c:.3f} is {ratio:.2f}x "
                f"the baseline {b:.3f} (max {args.max_ratio}x) and raw tuned "
                f"time grew {raw_delta:+.0f}us (floor {args.min_delta_us}us)")
        if c_row.get("speedup_vs_default", 1.0) < 1.0:
            failures.append(
                f"{name}: tuned tile is slower than the fixed default "
                f"(speedup {c_row['speedup_vs_default']}) — the tuner must "
                f"never lose to the default")
    if checked == 0:
        failures.append("no comparable kernel configs — baseline or current "
                        "file malformed?")
    if failures:
        print("\n".join(["KERNEL BENCH REGRESSION:"] + failures),
              file=sys.stderr)
        return 1
    print(f"kernel bench OK: {checked} configs within "
          f"{args.max_ratio}x of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
