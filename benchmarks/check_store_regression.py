"""CI gate: compare a fresh BENCH_store(_smoke).json against the committed
baseline and fail on incremental-materialization regressions.

Usage (what .github/workflows/ci.yml runs after ``store_cache.py --smoke``):

    python benchmarks/check_store_regression.py \
        --current BENCH_store_smoke.json \
        --baseline benchmarks/baselines/store_cache_baseline.json

Two kinds of check:

* **correctness booleans** — every entry in the current run's ``checks``
  must hold (warm run executes zero tasks, warm plan schedules no platform
  slots, backfill executes exactly the stale cone, cutoff executes exactly
  one task, ...).  These are machine-independent semantics; any failure is
  a regression outright.
* **warm speedup floor** — ``warm_speedup`` must stay above the baseline's
  ``min_warm_speedup``.  Raw wall-clock varies across runners, but the
  ratio is self-normalizing (cold and warm run in the same process on the
  same machine), and the floor (10x) sits far below the observed value
  (~400x+), so only a genuine cache-path regression — warm runs executing
  work, or bookkeeping blowing up — can trip it.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_store_smoke.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/store_cache_baseline.json")
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    failures: list[str] = []
    for name, ok in sorted(cur.get("checks", {}).items()):
        if not ok:
            failures.append(f"check failed: {name}")
    floor = base.get("min_warm_speedup", 10.0)
    speedup = cur.get("warm_speedup", 0.0)
    if speedup < floor:
        failures.append(f"warm speedup {speedup:.1f}x below the "
                        f"{floor:.0f}x floor")
    warm_exec = cur.get("warm", {}).get("tasks_executed", -1)
    if warm_exec != 0:
        failures.append(f"warm run executed {warm_exec} tasks (want 0)")

    print(f"store cache gate: warm {speedup:.0f}x (floor {floor:.0f}x), "
          f"{len(cur.get('checks', {}))} checks")
    if failures:
        for fmsg in failures:
            print(f"REGRESSION: {fmsg}", file=sys.stderr)
        return 1
    print("OK: no store-cache regression")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
