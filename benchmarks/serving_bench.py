"""Serving throughput sweep: paged engine vs the seed dense lockstep batcher.

Sweeps slots x arrival pattern x prompt-length mix on the gemma-2b smoke
model and writes ``BENCH_serving.json`` with, per scenario: tokens/s,
p50/p99 request latency (ticks), decode-tick wall p50/p99, prefill-stall
fraction, host-sync count and bytes moved.  Scenario families:

* ``dense_*``  — the seed ``ContinuousBatcher`` (4 lockstep slots, one host
  sync per tick, full prefill at admission that jit-retraces per novel
  prompt length).  This is the baseline the tentpole is measured against.
* ``paged_*``  — ``PagedServingEngine`` at 16/64 slots: chunked prefill on
  the bounded power-of-two ladder (no per-length retracing), device-resident
  decode blocks, drain-every-K host syncs.
* ``steady``   — paged engine, single-chunk prompts arriving at t=0: no
  prefill interleaving after the ramp, so its tick-wall median is the
  *no-prefill steady state* the p99 gate compares against.

The headline scenario (``*_mixed``) draws prompt lengths continuously from
[4, 60] — the serving reality the seed engine handles worst, because every
novel length costs it a full prefill recompile while the paged engine's
chunk ladder is warmed once.  The ``*_fixed`` scenarios repeat five warmed
lengths so the JSON also reports the no-retrace comparison honestly (on a
CPU, where compute scales linearly with batch, that ratio is far smaller;
on accelerators decode is memory-bound and large-batch ticks are ~free).

All gated numbers are in-run ratios (paged vs dense on the same machine in
the same sweep), so they are machine-independent: see
``check_serving_regression.py``.

Usage:
    python benchmarks/serving_bench.py            # full sweep
    python benchmarks/serving_bench.py --smoke    # CI subset, fewer requests
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gemma_2b import smoke
from repro.launch.serve import ContinuousBatcher, PagedServingEngine, Request
from repro.models import LanguageModel

FIXED_LENS = (4, 11, 23, 40, 57)


def make_trace(n_requests: int, kind: str, max_new: int, vocab: int,
               seed: int = 0, arrival_rate: float = 0.0) -> list[Request]:
    """Deterministic trace per seed so every engine sees identical requests.
    ``kind``: "mixed" = lengths uniform in [4, 60]; "fixed" = the five
    warmed lengths; "short" = single-chunk prompts.  ``arrival_rate`` 0 =
    burst at t=0, else geometric inter-arrival in ticks."""
    rng = np.random.RandomState(seed)
    reqs, t = [], 0
    for i in range(n_requests):
        if kind == "mixed":
            plen = int(rng.randint(4, 61))
        elif kind == "fixed":
            plen = FIXED_LENS[i % len(FIXED_LENS)]
        else:
            plen = int(rng.choice([3, 6, 9]))
        prompt = rng.randint(0, vocab, size=plen).tolist()
        reqs.append(Request(rid=i, prompt=prompt, max_new=max_new, arrival=t))
        if arrival_rate > 0:
            t += int(rng.geometric(min(1.0, arrival_rate)))
    return reqs


def clone(trace: list[Request]) -> list[Request]:
    return [Request(r.rid, list(r.prompt), r.max_new, r.arrival)
            for r in trace]


def run_scenario(engine, requests: list[Request]) -> dict:
    t0 = time.perf_counter()
    stats = engine.run(requests)
    stats["bench_wall_s"] = time.perf_counter() - t0
    return stats


def warm(engine, vocab: int) -> None:
    """Pay the engines' structural jit compiles before measurement: a
    63-token prompt hits the whole power-of-two chunk ladder (32+16+8+4+2+1)
    on the paged engine, and the five FIXED_LENS warm the dense batcher's
    per-length prefill traces for the ``*_fixed`` scenarios.  Novel lengths
    in the ``*_mixed`` traces still recompile on the dense engine — that is
    its real per-request cost, not a warmup artifact."""
    rng = np.random.RandomState(99)
    plens = [63, *FIXED_LENS]
    reqs = [Request(rid=-1 - i, prompt=rng.randint(0, vocab, p).tolist(),
                    max_new=3) for i, p in enumerate(plens)]
    engine.run(reqs)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: fewer requests, shorter generations")
    ap.add_argument("--out", default=None)
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args()

    cfg = smoke().scaled(compute_dtype="float32")
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_req = args.requests or (96 if args.smoke else 192)
    max_new = 24 if args.smoke else 48
    max_len = 128

    def paged(n_slots):
        return PagedServingEngine(model, params, n_slots=n_slots,
                                  max_len=max_len, page_size=16,
                                  chunk_max=32, drain_every=8,
                                  prefill_chunks_per_tick=4,
                                  dtype=jnp.float32)

    engines = {
        "dense": ContinuousBatcher(model, params, n_slots=4, max_len=max_len,
                                   enc_len=0),
        16: paged(16),
        64: paged(64),
    }
    for eng in engines.values():
        warm(eng, cfg.vocab_size)

    scenarios: dict[str, dict] = {}

    # --- warmed fixed-length burst: the no-retrace comparison -------------
    fixed_tr = make_trace(n_req, "fixed", max_new, cfg.vocab_size, seed=5)
    scenarios["dense_s4_fixed"] = run_scenario(engines["dense"],
                                               clone(fixed_tr))
    scenarios["paged_s64_fixed"] = run_scenario(engines[64], clone(fixed_tr))

    # --- headline: continuous mixed lengths, 64+ concurrent streams -------
    mixed_tr = make_trace(n_req, "mixed", max_new, cfg.vocab_size, seed=11)
    for n_slots in (16, 64):
        scenarios[f"paged_s{n_slots}_mixed"] = run_scenario(
            engines[n_slots], clone(mixed_tr))
    scenarios["dense_s4_mixed"] = run_scenario(engines["dense"],
                                               clone(mixed_tr))

    # --- arrival-rate sweep on the paged engine (full mode only) ----------
    if not args.smoke:
        for rate in (0.3, 1.0):
            tr = make_trace(n_req, "mixed", max_new, cfg.vocab_size,
                            seed=7, arrival_rate=rate)
            scenarios[f"paged_s64_mixed_r{rate}"] = run_scenario(
                engines[64], tr)

    # --- no-prefill steady state: single-chunk prompts, batch arrival -----
    steady_tr = make_trace(n_req, "short", max_new, cfg.vocab_size, seed=3)
    scenarios["steady_s64_short"] = run_scenario(engines[64], steady_tr)

    dense_tps = scenarios["dense_s4_mixed"]["tok_per_s"]
    paged_tps = scenarios["paged_s64_mixed"]["tok_per_s"]
    steady_p50 = scenarios["steady_s64_short"]["tick_ms_p50"]
    mixed_p99 = scenarios["paged_s64_mixed"]["tick_ms_p99"]
    out = {
        "mode": "cpu" if jax.devices()[0].platform == "cpu" else "accel",
        "model": "gemma-2b-smoke-f32",
        "n_requests": n_req,
        "max_new": max_new,
        "scenarios": scenarios,
        "summary": {
            "dense_tok_per_s": dense_tps,
            "paged64_tok_per_s": paged_tps,
            "speedup_64": paged_tps / max(dense_tps, 1e-9),
            "speedup_64_warm": (scenarios["paged_s64_fixed"]["tok_per_s"]
                                / max(scenarios["dense_s4_fixed"]
                                      ["tok_per_s"], 1e-9)),
            "steady_tick_ms_p50": steady_p50,
            "mixed_tick_ms_p99": mixed_p99,
            "p99_over_steady_p50": mixed_p99 / max(steady_p50, 1e-9),
        },
    }
    path = args.out or ("BENCH_serving_smoke.json" if args.smoke
                        else "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)

    s = out["summary"]
    print(f"dense 4-slot  : {dense_tps:8.1f} tok/s (mixed lengths)")
    print(f"paged 64-slot : {paged_tps:8.1f} tok/s "
          f"({s['speedup_64']:.2f}x; warm fixed-length "
          f"{s['speedup_64_warm']:.2f}x)")
    print(f"p99 tick {mixed_p99:.2f}ms vs steady p50 {steady_p50:.2f}ms "
          f"({s['p99_over_steady_p50']:.2f}x)")
    for name, sc in scenarios.items():
        print(f"  {name:>24}: {sc['tok_per_s']:8.1f} tok/s  "
              f"p99_lat {sc.get('p99_latency_ticks', -1.0):6.0f} ticks  "
              f"stall {sc.get('prefill_stall_fraction', 0.0):.3f}  "
              f"syncs {sc['host_syncs']}/{sc['ticks']} ticks")
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
