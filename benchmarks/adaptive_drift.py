"""Closed-loop adaptation benchmark: static plan vs adaptive coordinator
under injected platform drift on the Common-Crawl pipeline.

Reality diverges from the catalog on the *spot* platforms only: their
attempts run ``bias``x slower than the roofline estimate and suffer
failure/preemption rates the catalog never promised, while the premium
platform stays truthful.  Three drift levels:

* **none**   — reality matches the catalog exactly (no faults, bias 1.0);
* **mild**   — spot attempts 1.8x slow, preemptions up;
* **severe** — spot attempts 3.0x slow, 30% preemption, 10% hard failure.

Both arms start from the *same* static ``RunPlanner`` plan (min-cost: the
big ``edges`` tasks land on spot) and the same run id, so the deterministic
fault injection gives byte-identical behaviour until the closed loop
actually diverges:

* **static** — plain coordinator: per-task retries + failover only;
* **closed** — ``adaptive=AdaptiveConfig(...)``: the online cost model
  learns realized/predicted duration ratios from the early small ``nodes``
  tasks, the drift detector fires, and the coordinator replans the
  not-yet-launched ``edges``/``graph`` cone onto the truthful platform
  before the expensive work ever launches on the drifted one.

Checks: at zero drift the closed loop must match the static arm (it never
pays for adaptivity it does not need); at severe drift it must cut realized
slot-makespan by >= 15% and realized cost by > 0, via at least one adopted
replan.  ``check_adaptive_regression.py`` gates CI on these booleans plus
the makespan-reduction floor in
``benchmarks/baselines/adaptive_drift_baseline.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# make `python benchmarks/adaptive_drift.py` == `python -m benchmarks...`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from repro.core import (AdaptiveConfig, CostModel,  # noqa: E402
                        DynamicClientFactory, MessageReader, Objective,
                        RunCoordinator, SimulatedClusterClient, SlotConfig,
                        default_catalog)
from benchmarks.cc_pipeline import build_graph  # noqa: E402
from benchmarks.store_cache import _partitions  # noqa: E402

#: sleep = sim_duration * scale; edges ~8.6 h sim => ~0.9 s wall nominal,
#: so severe-drift static runs take seconds, not minutes
SIM_TIME_SCALE = 3e-5

#: injected *reality* on ``pod-spot`` — the platform every min-cost plan
#: relies on — while catalog beliefs stay untouched: a platform-local
#: incident (the paper's EMR-needs-oversight regime).  The other platforms
#: run clean, so rerouting is *possible*; the static plan just never does it
DRIFT_LEVELS = {
    "none": {"bias": 1.0, "failure": 0.0, "preemption": 0.0},
    "mild": {"bias": 1.8, "failure": 0.05, "preemption": 0.15},
    "severe": {"bias": 3.0, "failure": 0.10, "preemption": 0.30},
}

#: 2 slots per platform, no elastic growth: the pipeline drains in waves,
#: so the small nodes tasks finish (and teach the online model) before the
#: big edges tasks launch — the window a replan can act in
SLOTS = SlotConfig(max_concurrent=4, platform_slots=2, elastic_max_slots=2)

ADAPTIVE = AdaptiveConfig(replan_cooldown_s=0.05, breaker_cooldown_s=2.0)


def _client_builder(level: dict):
    def build(p):
        drifted = p.name == "pod-spot"
        return SimulatedClusterClient(
            p, sim_time_scale=SIM_TIME_SCALE,
            failure_rate=level["failure"] if drifted else 0.0,
            preemption_rate=level["preemption"] if drifted else 0.0,
            duration_bias=level["bias"] if drifted else 1.0)
    return build


def _coordinator(level: dict, parts, adaptive: bool) -> tuple[RunCoordinator,
                                                              MessageReader]:
    reader = MessageReader()
    # fleet catalog: clusters only (the free local platform is a debug
    # device and would win any min-cost argmin outright)
    catalog = {k: p for k, p in default_catalog().items() if k != "local"}
    factory = DynamicClientFactory(
        catalog, CostModel(), Objective.min_cost(),
        client_builder=_client_builder(level))
    coord = RunCoordinator(
        build_graph(partitions=parts), factory, reader=reader,
        slots=SLOTS, enable_speculation=False, use_cache=False,
        adaptive=ADAPTIVE if adaptive else None)
    return coord, reader


def _arm(name: str, level: dict, parts, run_id: str, plan,
         adaptive: bool) -> dict:
    coord, reader = _coordinator(level, parts, adaptive)
    t0 = time.perf_counter()
    report = coord.materialize("graph_aggr", run_id=run_id, plan=plan)
    wall_s = time.perf_counter() - t0
    replans = [e for e in reader.events() if e.kind == "REPLAN"]
    trips = [e for e in reader.events()
             if e.kind == "BREAKER" and e.payload.get("state") == "open"]
    edges_platforms = sorted({r.platform for r in report.records
                              if r.asset == "edges"})
    counts = reader.outcome_counts()
    return {
        "wall_s": round(wall_s, 3),
        "sim_makespan_s": round(report.slot_makespan_s(coord.slots), 1),
        "cost_usd": round(report.total_cost, 2),
        "attempts": sum(len(r.attempts) for r in report.records),
        "preemptions": sum(c.get("preemption", 0) for c in counts.values()),
        "failures": sum(c.get("failure", 0) for c in counts.values()),
        "replans_adopted": sum(1 for e in replans if e.payload.get("adopted")),
        "replan_reasons": (replans[0].payload.get("reasons", [])[:2]
                           if replans else []),
        "breaker_trips": len(trips),
        "edges_platforms": edges_platforms,
        "ok": report.ok,
    }


def _level(name: str, level: dict, parts) -> dict:
    # one static plan, priced by the *catalog* (it cannot see the drift),
    # shared by both arms — and one run id, so the deterministic fault
    # injection replays identically until the arms actually diverge
    plan_coord, _ = _coordinator(level, parts, adaptive=False)
    plan = plan_coord.plan("graph_aggr")
    run_id = f"adaptive-{name}"
    static = _arm("static", level, parts, run_id, plan, adaptive=False)
    closed = _arm("closed", level, parts, run_id, plan, adaptive=True)
    mk_red = 1.0 - closed["sim_makespan_s"] / max(static["sim_makespan_s"],
                                                  1e-9)
    cost_red = 1.0 - closed["cost_usd"] / max(static["cost_usd"], 1e-9)
    return {
        "drift": level,
        "static": static,
        "closed": closed,
        "makespan_reduction": round(mk_red, 4),
        "cost_reduction": round(cost_red, 4),
    }


def run(n_crawls: int, n_shards: int) -> dict:
    parts = _partitions(n_crawls, n_shards)
    levels = {name: _level(name, lv, parts)
              for name, lv in DRIFT_LEVELS.items()}
    none, severe = levels["none"], levels["severe"]
    checks = {
        # no drift -> no replan -> the two arms replay identically
        "zero_drift_parity_makespan": abs(none["makespan_reduction"]) <= 0.02,
        "zero_drift_parity_cost": abs(none["cost_reduction"]) <= 0.02,
        "zero_drift_no_replan": none["closed"]["replans_adopted"] == 0,
        "mild_no_regression": levels["mild"]["makespan_reduction"] >= -0.05,
        "severe_makespan_reduction_15pct":
            severe["makespan_reduction"] >= 0.15,
        "severe_cost_reduction": severe["cost_reduction"] > 0.0,
        "closed_loop_replanned": severe["closed"]["replans_adopted"] >= 1,
        "closed_loop_migrated_edges":
            severe["closed"]["edges_platforms"] != ["pod-spot"],
        "all_runs_ok": all(lv[arm]["ok"] for lv in levels.values()
                           for arm in ("static", "closed")),
    }
    return {
        "config": {"n_crawls": n_crawls, "n_shards": n_shards,
                   "n_tasks": 4 * n_crawls * n_shards,
                   "sim_time_scale": SIM_TIME_SCALE,
                   "slots": {"max_concurrent": SLOTS.max_concurrent,
                             "platform_slots": SLOTS.platform_slots}},
        "levels": levels,
        "checks": checks,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small partition grid for CI (16 tasks)")
    ap.add_argument("--out", default=None,
                    help="default BENCH_adaptive.json, or "
                         "BENCH_adaptive_smoke.json with --smoke")
    args = ap.parse_args()

    n_crawls, n_shards = (2, 2) if args.smoke else (3, 2)
    out = args.out or ("BENCH_adaptive_smoke.json" if args.smoke
                       else "BENCH_adaptive.json")
    result = run(n_crawls, n_shards)

    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    for name, lv in result["levels"].items():
        print(f"{name:7s} static {lv['static']['sim_makespan_s'] / 3600:7.1f} h "
              f"${lv['static']['cost_usd']:8.0f} | "
              f"closed {lv['closed']['sim_makespan_s'] / 3600:7.1f} h "
              f"${lv['closed']['cost_usd']:8.0f} | "
              f"makespan -{lv['makespan_reduction'] * 100:5.1f}% "
              f"cost -{lv['cost_reduction'] * 100:5.1f}% "
              f"(replans {lv['closed']['replans_adopted']}, "
              f"edges -> {','.join(lv['closed']['edges_platforms'])})")
    for name, ok in sorted(result["checks"].items()):
        print(f"  {'PASS' if ok else 'FAIL'} {name}")
    print(f"wrote {out}")
    if not all(result["checks"].values()):
        raise SystemExit("adaptive drift benchmark checks failed")


if __name__ == "__main__":
    main()
