"""Shared harness: the paper's 4-asset Common-Crawl pipeline wired into the
orchestrator, with Table-1-calibrated compute profiles.

Calibration (DESIGN.md §7): spot == EMR, premium == DBR.
Rates: spot $0.145/chip-h + 26% surcharge; premium 2.4x base + 48% surcharge;
work back-solved from Table 1 base costs (edges 2200 chip-h, graph 26,
nodes 2.3, graph_aggr 8) with right-sized clusters (CostModel.chips_for)
=> edges ~ $400/8.6h spot vs ~$730/5.7h premium, matching Table 1.
"""
from __future__ import annotations

from repro.core import (AssetGraph, ComputeProfile, CostModel,
                        DynamicClientFactory, MessageReader, MultiPartitions,
                        Objective, RetryPolicy, RunCoordinator,
                        StaticPartitions, TimeWindowPartitions, asset,
                        default_catalog)
from repro.data import commoncrawl as cc

CRAWLS = TimeWindowPartitions("2023-10", "2024-03")  # paper's access window
DOMAIN_SHARDS = StaticPartitions(("shard-0", "shard-1"))
PARTS = MultiPartitions(dims=(("time", CRAWLS), ("domain", DOMAIN_SHARDS)))
SMALL = MultiPartitions(dims=(
    ("time", StaticPartitions(("2023-10",))),
    ("domain", StaticPartitions(("shard-0",))),
))

# work_chip_hours back-solved from Table 1 base costs at the spot rate
# ($0.139/chip-h effective): work = base_usd / rate (see DESIGN.md §7)
PROFILES = {
    "nodes": ComputeProfile(work_chip_hours=2.3, speedup_class="light"),
    "edges": ComputeProfile(work_chip_hours=2200.0, speedup_class="scan"),
    "graph": ComputeProfile(work_chip_hours=26.0, speedup_class="shuffle"),
    "graph_aggr": ComputeProfile(work_chip_hours=8.0, speedup_class="light"),
}


def build_graph(cfg: cc.CrawlConfig | None = None,
                partitions=None, hints: dict | None = None,
                salt: dict | None = None) -> AssetGraph:
    """``salt`` (partition key -> token) is an external-input stand-in for
    the cache benchmarks: it is folded into the ``nodes`` *output* (new
    upstream data) without touching any compute function's source, so
    changing a partition's salt re-materializes exactly that partition's
    downstream cone — the shape of a real crawl-snapshot refresh."""
    cfg = cfg or cc.CrawlConfig(n_domains=32, n_pages_per_domain=4, n_seed=24,
                                max_links=6, tokens_per_page=32)
    hints = hints or {}
    salt = salt or {}
    parts = partitions if partitions is not None else PARTS
    retry = RetryPolicy(max_attempts=6, backoff_s=0.0, failover_after=2)

    def crawl_shard(ctx):
        dims = ctx.partition_key.split("/")
        return dims[0], dims[-1]

    @asset(name="nodes", partitions=parts, compute=PROFILES["nodes"],
           retry=retry, platform_hint=hints.get("nodes"))
    def nodes(ctx):
        crawl, shard = crawl_shard(ctx)
        out = cc.nodes_asset(crawl, shard, cfg)
        tok = salt.get(ctx.partition_key)
        if tok is not None:
            # a refreshed snapshot crawls different seed pages: rotate one
            # seed out so the new data propagates through every downstream
            # value (edges/graph/graph_aggr), not just this record
            out = {**out, "seed_pages": out["seed_pages"][1:], "salt": tok}
        return out

    @asset(name="edges", deps=("nodes",), partitions=parts,
           compute=PROFILES["edges"], retry=retry,
           platform_hint=hints.get("edges"))
    def edges(ctx, nodes):
        crawl, shard = crawl_shard(ctx)
        return cc.edges_asset(crawl, shard, nodes, cfg)

    @asset(name="graph", deps=("nodes", "edges"), partitions=parts,
           compute=PROFILES["graph"], retry=retry,
           platform_hint=hints.get("graph"))
    def graph(ctx, nodes, edges):
        return cc.graph_asset(nodes, edges)

    @asset(name="graph_aggr", deps=("graph",), partitions=parts,
           compute=PROFILES["graph_aggr"], retry=retry,
           platform_hint=hints.get("graph_aggr"))
    def graph_aggr(ctx, graph):
        return cc.graph_aggr_asset(graph, cfg)

    return AssetGraph([nodes, edges, graph, graph_aggr])


def run_policy(policy: str, seed: int = 0, partitions=None,
               objective: Objective | None = None):
    """policy: 'orchestrated' (dynamic factory) | 'planned' (DAG-level
    RunPlanner) | 'all-spot' | 'all-premium' | 'paper-mix' (run-1 of
    Table 1: edges on EMR, graph on DBR)."""
    hints = {}
    if policy == "all-spot":
        hints = {k: "pod-spot" for k in PROFILES}
    elif policy == "all-premium":
        hints = {k: "pod-premium" for k in PROFILES}
    elif policy == "paper-mix":
        hints = {"nodes": "pod-spot", "edges": "pod-spot",
                 "graph": "pod-premium", "graph_aggr": "pod-spot"}
    g = build_graph(partitions=partitions, hints=hints)
    reader = MessageReader()
    factory = DynamicClientFactory(default_catalog(), CostModel(),
                                   objective or Objective.balanced(),
                                   sim_seed=seed)
    coord = RunCoordinator(g, factory, reader=reader, use_cache=False)
    plan = coord.plan(["graph_aggr"]) if policy == "planned" else None
    report = coord.materialize(["graph_aggr"], run_id=f"{policy}-{seed}",
                               plan=plan)
    return report, reader
