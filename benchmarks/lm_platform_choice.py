"""The paper's decision logic applied to the LM substrate: feed each
(arch x shape) cell's dry-run roofline terms into the Dynamic Factory and
let the cost model choose the execution platform + price the job.

This is the end-to-end integration of the two halves of the framework — the
orchestrator prices LM training/serving assets exactly the way it prices the
paper's Common-Crawl assets (DESIGN.md §2): duration = max(compute, memory,
collective roofline term) x steps / perf_factor; cost = Table-1 structure.
"""
from __future__ import annotations

import glob
import json
import os

from repro.core import (ComputeProfile, CostModel, DynamicClientFactory,
                        Objective, asset, default_catalog)

ART_DIR = os.environ.get("REPRO_DRYRUN_DIR", "artifacts/dryrun")


def profile_from_cell(cell: dict, steps: int = 1000) -> ComputeProfile:
    n = cell["n_chips"]
    return ComputeProfile(
        flops=cell["analytic_flops_per_device"] * n * steps,
        bytes_hbm=cell["analytic_hbm_bytes_per_device"] * n * steps,
        collective_bytes=cell["collective_bytes"]["total"] * n * steps,
        speedup_class="train" if cell["kind"] == "train" else "serve",
        min_chips=64,
        memory_gb_per_chip=(cell["memory_analysis"]
                            .get("argument_size_in_bytes", 0) / 2**30),
    )


def run(steps: int = 1000) -> dict:
    factory = DynamicClientFactory(default_catalog(), CostModel(),
                                   Objective.balanced(), sim_seed=0)
    out = {}
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*__16x16.json"))):
        with open(path) as f:
            cell = json.load(f)
        if cell.get("status") != "ok":
            continue
        name = f"{cell['arch']}:{cell['shape']}"
        spec = asset(name=name, compute=profile_from_cell(cell, steps))(
            lambda ctx: None)
        platform, est = factory.choose(spec)
        out[name] = {
            "platform": platform.name,
            "duration_h": round(est.duration_s / 3600.0, 2),
            "cost_usd": round(est.total_usd, 2),
            "surcharge_usd": round(est.surcharge_usd, 2),
        }
    return out


if __name__ == "__main__":
    table = run()
    print(f"{'cell':<38} {'platform':<16} {'hours':>7} {'cost':>10}")
    for k, v in table.items():
        print(f"{k:<38} {v['platform']:<16} {v['duration_h']:>7.2f} "
              f"${v['cost_usd']:>9.2f}")
